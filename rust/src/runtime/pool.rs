//! Persistent, deterministic worker pool — the execution substrate behind
//! [`crate::gossip::ExecPolicy::Parallel`].
//!
//! The first parallel engine (PR 3) spawned scoped threads per round:
//! borrow-safe and dependency-free, but ~2·shards thread spawns *every
//! gossip round* — a fixed tax that dominates exactly in the large-N
//! regimes (dozens to thousands of nodes) the paper's scaling argument
//! targets. This pool replaces the per-round spawns with **long-lived
//! workers** and a per-round barrier handoff:
//!
//! * **Long-lived workers.** `Pool::new(threads)` spawns its workers once;
//!   they park on a condvar between rounds. Dispatching a round is two
//!   uncontended lock acquisitions and two condvar signals — no thread
//!   creation, no heap allocation, no channel traffic on the steady path.
//! * **Epoch handoff.** [`Pool::run`] publishes the round's job (a borrowed
//!   `Fn(usize)` closure, lifetime-erased) together with a
//!   bumped epoch counter, wakes the workers, and blocks until every
//!   worker reports back. Because `run` does not return while any worker
//!   can still touch the job, the borrow never escapes — the `unsafe`
//!   lifetime erasure is confined to that window.
//! * **Shard→worker pinning.** Worker `w` of `W` executes exactly the jobs
//!   `{ j : j ≡ w (mod W) }`, every round. The assignment is a pure
//!   function of `(jobs, workers)` — never of scheduling timing — so a
//!   shard's scratch state is always touched by the same worker and the
//!   engine's bit-identity contract holds at **any** thread count (the
//!   values never depend on which worker ran a shard; pinning additionally
//!   keeps the execution layout reproducible run-to-run for perf work).
//!
//! The process-global pool ([`global`]) sizes itself to the machine (or
//! `SGP_POOL_THREADS`); sweeps and tests that need an explicit thread
//! count build private pools ([`Pool::new`]) and hand them to the engine
//! via [`crate::gossip::PushSumEngine::set_pool`].

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::rng::Pcg;

thread_local! {
    /// Set while the current thread is executing a pool job. A nested
    /// [`Pool::run`] from a job would deadlock on the dispatch mutex
    /// (the outer dispatcher waits for this worker, which waits for the
    /// dispatch lock); this flag turns that silent hang into a panic.
    static IN_POOL_JOB: Cell<bool> = const { Cell::new(false) };
}

/// The lifetime-erased job of one round: run shard `j`. The `'static` is
/// a fiction maintained by the epoch protocol — the reference is only
/// called while the dispatching [`Pool::run`] keeps the real (shorter)
/// borrow alive, and the slot is cleared before `run` returns.
#[derive(Clone, Copy)]
struct JobPtr(&'static (dyn Fn(usize) + Sync));

/// Seeded wake-order permutation for the barrier — the dynamic leg of
/// the `repro audit` determinism story (see ARCHITECTURE.md §8 and
/// `rust/tests/pool_interleaving.rs`).
///
/// With a plan installed ([`Pool::set_wake_plan`]), the workers of each
/// epoch pass a start gate in the order of a per-epoch Fisher–Yates
/// shuffle drawn from `(seed, epoch)`: worker scan *start* order is
/// forced through every seeded permutation while the jobs themselves
/// still overlap freely. Shard→worker pinning claims the engine output
/// is a pure function of the job set — a plan lets tests drive hostile
/// wake orders through the condvar protocol and assert bit-identical
/// results plus exactly-once dispatch under all of them. `None` (the
/// default) leaves the barrier's production path untouched.
#[derive(Debug, Clone, Copy)]
pub struct WakePlan {
    seed: u64,
}

impl WakePlan {
    /// A plan permuting worker wake order by `seed`, re-drawn per epoch.
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// Worker `w`'s position in epoch `epoch`'s start order — a pure
    /// function of `(seed, epoch, workers)`, identical across runs.
    fn rank(self, epoch: u64, w: usize, workers: usize) -> usize {
        let mut order: Vec<usize> = (0..workers).collect();
        Pcg::with_stream(self.seed, epoch).shuffle(&mut order);
        order.iter().position(|&c| c == w).unwrap_or(0)
    }
}

/// Shared dispatch state, guarded by one mutex.
struct Shared {
    /// Round counter; workers run one scan per observed increment.
    epoch: u64,
    /// The published job of the current epoch (`None` outside a round).
    job: Option<JobPtr>,
    /// Number of jobs (shards) in the current epoch.
    jobs: usize,
    /// Workers that have finished scanning the current epoch.
    done: usize,
    /// Workers that have passed the current epoch's start gate (only
    /// consulted while a [`WakePlan`] is installed).
    started: usize,
    /// Set when a job panicked inside a worker this epoch.
    panicked: bool,
    /// Set by `Drop` to terminate the workers.
    shutdown: bool,
    /// Test-only wake-order permutation; `None` in production.
    plan: Option<WakePlan>,
}

struct Inner {
    state: Mutex<Shared>,
    /// Workers park here between rounds.
    work_cv: Condvar,
    /// The dispatching thread parks here until `done == workers`.
    done_cv: Condvar,
}

/// Lock with panic-poisoning recovery: a panicked job never leaves the
/// dispatch state inconsistent (all mutations happen under short critical
/// sections that cannot panic), so a poisoned mutex is safe to re-enter.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Erase the borrow lifetime of a job reference (identical fat-pointer
/// layout either side).
///
/// # Safety
/// The caller must guarantee the referent outlives every call made through
/// the returned reference — [`Pool::run`] does so by blocking until all
/// workers have finished the epoch.
unsafe fn erase(f: &(dyn Fn(usize) + Sync)) -> &'static (dyn Fn(usize) + Sync) {
    std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
}

/// A persistent worker pool with deterministic shard→worker pinning.
///
/// ```
/// use sgp::runtime::pool::Pool;
/// use std::sync::atomic::{AtomicU64, Ordering};
///
/// let pool = Pool::new(3);
/// let hits = AtomicU64::new(0);
/// pool.run(8, &|j| {
///     hits.fetch_add(1u64 << (8 * (j % 8)), Ordering::Relaxed);
/// });
/// // Every job ran exactly once, whichever worker was pinned to it.
/// assert_eq!(hits.load(Ordering::Relaxed), 0x0101_0101_0101_0101);
/// ```
pub struct Pool {
    inner: std::sync::Arc<Inner>,
    /// Serializes dispatches: two threads driving engines through the same
    /// (e.g. global) pool take turns round-by-round instead of corrupting
    /// the epoch protocol. Held for the whole barrier window.
    dispatch: Mutex<()>,
    workers: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Barrier dispatches completed (multi-job rounds only — inline
    /// `jobs ≤ 1` calls never touch the barrier). Only counted while
    /// [`Pool::set_metered`] is on.
    dispatches: AtomicU64,
    /// Total nanoseconds the dispatching threads spent inside the
    /// barrier window (publish → all workers done), cumulative. Only
    /// accumulated while [`Pool::set_metered`] is on.
    run_ns: AtomicU64,
    /// Gates the barrier-window timing: an `Instant::now()` pair plus
    /// two atomic adds per dispatch is a small but real tax on the hot
    /// path the perf gate guards, so it is paid only when an observer
    /// has asked for [`Pool::dispatch_stats`].
    metered: AtomicBool,
}

impl Pool {
    /// Spawn a pool of `threads` long-lived workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        let workers = threads.max(1);
        let inner = std::sync::Arc::new(Inner {
            state: Mutex::new(Shared {
                epoch: 0,
                job: None,
                jobs: 0,
                done: 0,
                started: 0,
                panicked: false,
                shutdown: false,
                plan: None,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|w| {
                let inner = std::sync::Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("sgp-pool-{w}"))
                    .spawn(move || worker_loop(&inner, w, workers))
                    .expect("spawning pool worker")
            })
            .collect();
        Self {
            inner,
            dispatch: Mutex::new(()),
            workers,
            handles,
            dispatches: AtomicU64::new(0),
            run_ns: AtomicU64::new(0),
            metered: AtomicBool::new(false),
        }
    }

    /// Number of workers in this pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Observability counters: `(dispatches, total_barrier_ns)` — how many
    /// multi-job rounds this pool has dispatched and the cumulative wall
    /// time its dispatching threads spent in the barrier window. Both are
    /// monotone (relaxed atomics), so callers diff two snapshots to meter
    /// a span; on a shared (e.g. global) pool the diff upper-bounds the
    /// caller's own share. Counted only while metering is enabled
    /// ([`Pool::set_metered`]) — observers enable it before their first
    /// snapshot.
    pub fn dispatch_stats(&self) -> (u64, u64) {
        (self.dispatches.load(Ordering::Relaxed), self.run_ns.load(Ordering::Relaxed))
    }

    /// Enable (or disable) dispatch metering. Off by default so the
    /// barrier hot path pays no clock reads or atomic adds when nothing
    /// reads [`Pool::dispatch_stats`]; an engine with an observability
    /// recorder attached turns it on. On a shared pool metering stays on
    /// for every concurrent user once any observer enables it.
    pub fn set_metered(&self, on: bool) {
        self.metered.store(on, Ordering::Relaxed);
    }

    /// Install (or clear) a [`WakePlan`]. Takes effect from the next
    /// dispatched epoch; a test-only hook — production dispatch never
    /// sets one, keeping the barrier's hot path free of the gate.
    pub fn set_wake_plan(&self, plan: Option<WakePlan>) {
        lock(&self.inner.state).plan = plan;
    }

    /// Execute `f(0) … f(jobs-1)` across the pool and wait for all of them:
    /// one barrier handoff, zero heap allocations. Job `j` always runs on
    /// worker `j % workers` (shard→worker pinning). `jobs == 0` returns
    /// immediately; `jobs == 1` runs inline on the caller (a single shard
    /// has nothing to overlap with, and skipping the handoff keeps the
    /// degenerate case as cheap as a direct call).
    ///
    /// Panics (after completing the barrier) if any job panicked.
    ///
    /// Not reentrant: a job must never dispatch to any pool (dispatching
    /// to its own pool would deadlock — the dispatcher waits on the very
    /// worker that is waiting on the dispatch lock). Nested dispatch from
    /// a job panics immediately instead of hanging. Concurrent `run`
    /// calls from different threads are safe — they serialize, round by
    /// round.
    // audit: zero-alloc
    pub fn run(&self, jobs: usize, f: &(dyn Fn(usize) + Sync)) {
        if jobs == 0 {
            return;
        }
        if jobs == 1 {
            f(0);
            return;
        }
        assert!(
            !IN_POOL_JOB.get(),
            "Pool::run dispatched from inside a pool job — nested dispatch \
             deadlocks; restructure the caller to dispatch from the \
             coordinating thread"
        );
        let _turn = lock(&self.dispatch);
        let t0 = self.metered.load(Ordering::Relaxed).then(Instant::now);
        // SAFETY: the erased reference is only callable by workers woken
        // for this epoch, and this call does not return until every worker
        // has reported done — the real borrow outlives every call.
        let job = JobPtr(unsafe { erase(f) });
        {
            let mut st = lock(&self.inner.state);
            debug_assert!(st.job.is_none(), "Pool::run is not reentrant");
            st.job = Some(job);
            st.jobs = jobs;
            st.done = 0;
            st.started = 0;
            st.panicked = false;
            st.epoch += 1;
        }
        self.inner.work_cv.notify_all();

        let mut st = lock(&self.inner.state);
        while st.done < self.workers {
            st = self
                .inner
                .done_cv
                .wait(st)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        st.job = None;
        let panicked = st.panicked;
        drop(st);
        if let Some(t0) = t0 {
            self.dispatches.fetch_add(1, Ordering::Relaxed);
            self.run_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        if panicked {
            panic!("a pool worker job panicked");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.inner.state);
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One worker's life: wait for a new epoch, run the jobs pinned to this
/// worker (`j ≡ w mod workers`, ascending), report done, repeat.
///
/// With a [`WakePlan`] installed, each worker additionally holds at a
/// start gate until every worker the plan ranks before it has passed:
/// scan *start* order follows the seeded permutation exactly, while job
/// execution still overlaps. The gate cannot deadlock — the plan's ranks
/// are a permutation of `0..workers`, so exactly one gated worker matches
/// the current `started` count, and every increment (and shutdown)
/// notifies all waiters.
// audit: zero-alloc
fn worker_loop(inner: &Inner, w: usize, workers: usize) {
    let mut seen = 0u64;
    loop {
        let (job, jobs) = {
            let mut st = lock(&inner.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = inner
                    .work_cv
                    .wait(st)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            seen = st.epoch;
            if let Some(plan) = st.plan {
                let rank = plan.rank(seen, w, workers);
                while st.started < rank && !st.shutdown {
                    st = inner
                        .work_cv
                        .wait(st)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                }
                if st.shutdown {
                    return;
                }
                st.started += 1;
                inner.work_cv.notify_all();
            }
            (st.job.expect("epoch published without a job"), st.jobs)
        };
        let mut panicked = false;
        let mut j = w;
        IN_POOL_JOB.set(true);
        while j < jobs {
            // The dispatching `run` call keeps the job's real borrow alive
            // until every worker (this one included) has incremented `done`.
            let f = job.0;
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(j)))
                .is_err()
            {
                panicked = true;
            }
            j += workers;
        }
        IN_POOL_JOB.set(false);
        let mut st = lock(&inner.state);
        st.done += 1;
        st.panicked |= panicked;
        if st.done == workers {
            inner.done_cv.notify_one();
        }
        drop(st);
    }
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// The process-global pool every [`crate::gossip::ExecPolicy::Parallel`]
/// engine round dispatches to unless an explicit pool was attached
/// ([`crate::gossip::PushSumEngine::set_pool`]). Sized once, lazily, from
/// `SGP_POOL_THREADS` when set (≥ 1) or the machine's available
/// parallelism otherwise.
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(|| {
        let threads = std::env::var("SGP_POOL_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
            });
        Pool::new(threads)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_job_exactly_once() {
        let pool = Pool::new(4);
        for jobs in [0usize, 1, 2, 3, 4, 7, 16, 33] {
            let counts: Vec<AtomicUsize> =
                (0..jobs).map(|_| AtomicUsize::new(0)).collect();
            pool.run(jobs, &|j| {
                counts[j].fetch_add(1, Ordering::Relaxed);
            });
            for (j, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "jobs={jobs} j={j}");
            }
        }
    }

    #[test]
    fn reusable_across_many_rounds() {
        let pool = Pool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(5, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn pinning_is_stable_across_rounds() {
        // Job j must land on the same worker every round: record the
        // executing thread per job and compare across rounds.
        let pool = Pool::new(3);
        let round_a: Vec<Mutex<Option<std::thread::ThreadId>>> =
            (0..7).map(|_| Mutex::new(None)).collect();
        pool.run(7, &|j| {
            *round_a[j].lock().unwrap() = Some(std::thread::current().id());
        });
        let round_b: Vec<Mutex<Option<std::thread::ThreadId>>> =
            (0..7).map(|_| Mutex::new(None)).collect();
        pool.run(7, &|j| {
            *round_b[j].lock().unwrap() = Some(std::thread::current().id());
        });
        for j in 0..7 {
            let a = round_a[j].lock().unwrap().expect("job ran");
            let b = round_b[j].lock().unwrap().expect("job ran");
            assert_eq!(a, b, "job {j} migrated between rounds");
        }
        // And jobs j, j+workers share a worker (the pinning rule).
        let a0 = round_a[0].lock().unwrap().unwrap();
        let a3 = round_a[3].lock().unwrap().unwrap();
        let a6 = round_a[6].lock().unwrap().unwrap();
        assert_eq!(a0, a3);
        assert_eq!(a3, a6);
    }

    #[test]
    fn single_worker_pool_still_completes() {
        let pool = Pool::new(1);
        let total = AtomicUsize::new(0);
        pool.run(9, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn worker_panic_is_reported_not_deadlocked() {
        let pool = Pool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|j| {
                if j == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the dispatcher");
        // The pool is still usable after a failed round.
        let total = AtomicUsize::new(0);
        pool.run(4, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn nested_dispatch_from_a_job_panics_instead_of_deadlocking() {
        // A job dispatching to its own pool is a deadlock by construction;
        // the thread-local guard must turn it into a loud, contained panic
        // (the worker catches it, the dispatcher re-raises it).
        let pool = Pool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, &|_| {
                pool.run(2, &|_| {});
            });
        }));
        assert!(result.is_err(), "nested dispatch must panic, not hang");
        // The pool remains usable afterwards.
        let total = AtomicUsize::new(0);
        pool.run(3, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn dispatch_stats_count_multi_job_rounds_only_when_metered() {
        let pool = Pool::new(2);
        let (d0, ns0) = pool.dispatch_stats();
        assert_eq!((d0, ns0), (0, 0), "fresh pool starts at zero");
        pool.run(4, &|_| {});
        assert_eq!(pool.dispatch_stats(), (0, 0), "unmetered dispatches are free");
        pool.set_metered(true);
        pool.run(0, &|_| {});
        pool.run(1, &|_| {});
        assert_eq!(pool.dispatch_stats().0, 0, "inline paths skip the barrier");
        for _ in 0..3 {
            pool.run(4, &|_| {});
        }
        let (d, ns) = pool.dispatch_stats();
        assert_eq!(d, 3, "one dispatch per metered multi-job round");
        assert!(ns > 0, "barrier wall time accumulates");
        pool.set_metered(false);
        pool.run(4, &|_| {});
        assert_eq!(pool.dispatch_stats().0, 3, "metering can be switched back off");
    }

    #[test]
    fn wake_plan_ranks_form_a_permutation_every_epoch() {
        for workers in [1usize, 2, 3, 5, 8] {
            for seed in [0u64, 1, 42, 0xDEAD_BEEF] {
                let plan = WakePlan::new(seed);
                for epoch in [1u64, 2, 3, 100] {
                    let mut seen = vec![false; workers];
                    for w in 0..workers {
                        let r = plan.rank(epoch, w, workers);
                        assert!(r < workers, "rank in range");
                        assert!(!seen[r], "rank {r} assigned twice (seed {seed})");
                        seen[r] = true;
                    }
                    // And the rank is reproducible — same inputs, same order.
                    for w in 0..workers {
                        assert_eq!(
                            plan.rank(epoch, w, workers),
                            plan.rank(epoch, w, workers)
                        );
                    }
                }
            }
        }
        // Different epochs actually permute (not a fixed order): some epoch
        // pair must disagree for a 5-worker pool.
        let plan = WakePlan::new(7);
        let differs = (2u64..20).any(|e| {
            (0..5).any(|w| plan.rank(1, w, 5) != plan.rank(e, w, 5))
        });
        assert!(differs, "wake order must vary across epochs");
    }

    #[test]
    fn wake_plan_gates_dispatch_and_is_clearable() {
        let pool = Pool::new(3);
        pool.set_wake_plan(Some(WakePlan::new(99)));
        for _ in 0..50 {
            let counts: Vec<AtomicUsize> =
                (0..7).map(|_| AtomicUsize::new(0)).collect();
            pool.run(7, &|j| {
                counts[j].fetch_add(1, Ordering::Relaxed);
            });
            for (j, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "job {j} exactly once");
            }
        }
        pool.set_wake_plan(None);
        let total = AtomicUsize::new(0);
        pool.run(5, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 5, "plan cleared cleanly");
    }

    #[test]
    fn global_pool_is_sized_and_reused() {
        let p1 = global() as *const Pool;
        let p2 = global() as *const Pool;
        assert_eq!(p1, p2);
        assert!(global().workers() >= 1);
    }
}

//! PJRT runtime: loads the HLO-text artifacts produced by `make artifacts`
//! and executes them from the training hot path.
//!
//! Wiring (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled once on first use and cached; Python is never
//! involved at runtime.
//!
//! The runtime layer also owns the [`pool`] submodule: the persistent
//! worker pool the parallel gossip engine (and the sharded timing /
//! collective helpers) dispatch to instead of spawning scoped threads per
//! round.

pub mod pool;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::data::Batch;
use crate::model::Manifest;

/// The PJRT bridge: a CPU client plus the loaded artifact manifest and a
/// compile-on-first-use executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    /// Artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// The parsed artifact manifest.
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Executions performed (diagnostics / perf accounting).
    pub exec_count: RefCell<u64>,
}

impl Runtime {
    /// Load the manifest from `dir` and connect the CPU PJRT client.
    pub fn new(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    /// Open the default artifacts directory.
    pub fn open_default() -> Result<Self> {
        Self::new(crate::model::artifacts_dir())
    }

    /// Compile-on-first-use executable cache.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.manifest.artifact(name)?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling `{name}`: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        *self.exec_count.borrow_mut() += 1;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing `{name}`: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching `{name}` result: {e:?}"))?;
        result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling `{name}` result: {e:?}"))
    }

    fn lit_f32(xs: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let l = xla::Literal::vec1(xs);
        if dims.len() == 1 {
            return Ok(l);
        }
        let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        l.reshape(&d).map_err(|e| anyhow::anyhow!("reshape f32: {e:?}"))
    }

    fn lit_i32(xs: &[i32], dims: &[usize]) -> Result<xla::Literal> {
        let l = xla::Literal::vec1(xs);
        if dims.len() == 1 {
            return Ok(l);
        }
        let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        l.reshape(&d).map_err(|e| anyhow::anyhow!("reshape i32: {e:?}"))
    }

    fn batch_literals(batch: &Batch) -> Result<Vec<xla::Literal>> {
        Ok(match batch {
            Batch::Classif { x, y, b, in_dim } => vec![
                Self::lit_f32(x, &[*b, *in_dim])?,
                Self::lit_i32(y, &[y.len()])?,
            ],
            Batch::Tokens { t, b, seq } => {
                vec![Self::lit_i32(t, &[*b, *seq + 1])?]
            }
        })
    }

    /// Run `train_<model>`: (params, batch…) → (loss, grads).
    pub fn train_step(
        &self,
        model: &str,
        params: &[f32],
        batch: &Batch,
    ) -> Result<(f32, Vec<f32>)> {
        let name = format!("train_{model}");
        let mut args = vec![Self::lit_f32(params, &[params.len()])?];
        args.extend(Self::batch_literals(batch)?);
        let out = self.run(&name, &args)?;
        anyhow::ensure!(out.len() == 2, "train step returned {} outputs", out.len());
        let loss = out[0]
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?;
        let grads =
            out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("grads: {e:?}"))?;
        anyhow::ensure!(grads.len() == params.len(), "grad length mismatch");
        Ok((loss, grads))
    }

    /// Run `eval_<model>`: (params, batch…) → (loss, metric).
    pub fn eval_step(
        &self,
        model: &str,
        params: &[f32],
        batch: &Batch,
    ) -> Result<(f32, f32)> {
        let name = format!("eval_{model}");
        let mut args = vec![Self::lit_f32(params, &[params.len()])?];
        args.extend(Self::batch_literals(batch)?);
        let out = self.run(&name, &args)?;
        anyhow::ensure!(out.len() == 2, "eval step returned {} outputs", out.len());
        let loss = out[0]
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?;
        let metric = out[1]
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("metric: {e:?}"))?;
        Ok((loss, metric))
    }

    /// Run the fused Nesterov artifact (ablation path): returns (x', u').
    pub fn update_sgdm(
        &self,
        name: &str,
        x: &[f32],
        u: &[f32],
        g: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let args = vec![
            Self::lit_f32(x, &[x.len()])?,
            Self::lit_f32(u, &[u.len()])?,
            Self::lit_f32(g, &[g.len()])?,
            Self::lit_f32(&[lr], &[1])?,
        ];
        let out = self.run(name, &args)?;
        anyhow::ensure!(out.len() == 2, "sgdm returned {} outputs", out.len());
        Ok((
            out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        ))
    }

    /// Run the fused Adam artifact: returns (x', m', v').
    #[allow(clippy::too_many_arguments)]
    pub fn update_adam(
        &self,
        name: &str,
        x: &[f32],
        m: &[f32],
        v: &[f32],
        g: &[f32],
        lr: f32,
        t: u64,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let scalars = [
            lr,
            1.0 - 0.9f32.powi(t as i32),
            1.0 - 0.98f32.powi(t as i32),
        ];
        let args = vec![
            Self::lit_f32(x, &[x.len()])?,
            Self::lit_f32(m, &[m.len()])?,
            Self::lit_f32(v, &[v.len()])?,
            Self::lit_f32(g, &[g.len()])?,
            Self::lit_f32(&scalars, &[3])?,
        ];
        let out = self.run(name, &args)?;
        anyhow::ensure!(out.len() == 3, "adam returned {} outputs", out.len());
        Ok((
            out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            out[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        ))
    }

    /// Run the dense push-sum round (gossip-as-matmul Pallas artifact):
    /// `P ∈ f32[n,n]`, `x ∈ f32[n·d]` row-major, `w ∈ f32[n]` → (x', w', z').
    pub fn gossip_dense(
        &self,
        n: usize,
        p: &[f32],
        x: &[f32],
        w: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let name = format!("gossip_dense_n{n}");
        let d = x.len() / n;
        let args = vec![
            Self::lit_f32(p, &[n, n])?,
            Self::lit_f32(x, &[n, d])?,
            Self::lit_f32(w, &[n])?,
        ];
        let out = self.run(&name, &args)?;
        anyhow::ensure!(out.len() == 3, "gossip returned {} outputs", out.len());
        Ok((
            out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
            out[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?,
        ))
    }

    /// Message size in bytes for a model's parameters + push-sum weight —
    /// what one SGP message carries over the simulated network.
    pub fn message_bytes(&self, model: &str) -> Result<usize> {
        Ok(self.manifest.model(model)?.param_count * 4 + 8)
    }
}

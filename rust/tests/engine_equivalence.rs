//! The parallel execution engine's determinism contract, property-tested:
//! at a fixed seed, the sharded engine is **bit-identical** to the
//! sequential engine — parameters, push-sum weights, consensus distance,
//! in-flight mailboxes, the fault ledger and the fault counters — for
//! random topologies, random fault plans (drops, rescue, crash/rejoin,
//! permanent leaves) and shard counts in {1, 2, 7}.
//!
//! Same generator style as `prop_invariants.rs`: the offline build has no
//! proptest, so cases are drawn from seeded [`Pcg`] streams and the
//! failing case's seed is printed in the assert message.

use sgp::faults::{FaultClock, FaultPlan};
use sgp::gossip::{Compression, ExecPolicy, PushSumEngine};
use sgp::net::{CommPattern, ComputeModel, LinkModel, OwnedCommPattern, TimingSim};
use sgp::rng::Pcg;
use sgp::topology::{Schedule, TopologyKind};

const KINDS: &[TopologyKind] = &[
    TopologyKind::OnePeerExp,
    TopologyKind::TwoPeerExp,
    TopologyKind::Complete,
    TopologyKind::CompleteCycling,
    TopologyKind::RandomExp,
    TopologyKind::RandomAny,
    TopologyKind::Ring,
    TopologyKind::BipartiteExp,
];

const SHARDS: &[usize] = &[1, 2, 7];

fn arb_n(rng: &mut Pcg) -> usize {
    [2, 3, 5, 8, 13, 16, 32][rng.below(7)]
}

/// Random fault plan: drop rate, maybe rescue, up to two crashes
/// (rejoining or permanent).
fn arb_plan(rng: &mut Pcg, n: usize, horizon: u64, seed: u64) -> FaultPlan {
    let mut plan = FaultPlan::lossless()
        .with_drop(rng.f64() * 0.3)
        .with_rescue(rng.f64() < 0.5)
        .with_seed(seed);
    for _ in 0..rng.below(3) {
        let node = rng.below(n);
        let at = rng.next_u64() % horizon.max(1);
        let rejoin = if rng.f64() < 0.5 {
            Some(at + 1 + rng.next_u64() % horizon.max(1))
        } else {
            None
        };
        plan = plan.with_crash(node, at, rejoin);
    }
    plan
}

/// Assert the two engines hold exactly the same bits everywhere the
/// contract covers.
fn assert_engines_identical(seq: &PushSumEngine, par: &PushSumEngine, tag: &str) {
    for (i, (a, b)) in seq.states.iter().zip(&par.states).enumerate() {
        assert_eq!(a.x, b.x, "{tag}: node {i} numerator diverged");
        assert_eq!(
            a.w.to_bits(),
            b.w.to_bits(),
            "{tag}: node {i} push-sum weight diverged"
        );
    }
    assert_eq!(seq.in_flight(), par.in_flight(), "{tag}: in-flight count");
    assert_eq!(seq.drop_count, par.drop_count, "{tag}: drop counter");
    assert_eq!(seq.rescue_count, par.rescue_count, "{tag}: rescue counter");
    let (dxa, dwa) = seq.dropped_mass();
    let (dxb, dwb) = par.dropped_mass();
    assert_eq!(dwa.to_bits(), dwb.to_bits(), "{tag}: dropped w ledger");
    for (a, b) in dxa.iter().zip(dxb) {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: dropped x ledger");
    }
    let (ca, cb) = (seq.consensus_distance(), par.consensus_distance());
    assert_eq!(ca.0.to_bits(), cb.0.to_bits(), "{tag}: consensus mean");
    assert_eq!(ca.1.to_bits(), cb.1.to_bits(), "{tag}: consensus min");
    assert_eq!(ca.2.to_bits(), cb.2.to_bits(), "{tag}: consensus max");
}

#[test]
fn prop_parallel_engine_bit_identical_clean() {
    for case in 0..40u64 {
        let mut rng = Pcg::new(20_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let dim = 1 + rng.below(24);
        let delay = rng.below(4) as u64;
        let biased = rng.f64() < 0.2;
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
        let sched = Schedule::with_seed(kind, n, case);
        for &shards in SHARDS {
            let tag = format!(
                "case {case}: {kind:?} n={n} dim={dim} delay={delay} \
                 biased={biased} shards={shards}"
            );
            let mut seq = PushSumEngine::new(init.clone(), delay, biased);
            let mut par = PushSumEngine::new(init.clone(), delay, biased);
            for k in 0..25 {
                seq.step_exec(k, &sched, None, ExecPolicy::Sequential);
                par.step_exec(k, &sched, None, ExecPolicy::parallel(shards));
            }
            assert_engines_identical(&seq, &par, &tag);
            seq.drain();
            par.drain();
            assert_engines_identical(&seq, &par, &format!("{tag} (drained)"));
        }
    }
}

#[test]
fn prop_parallel_engine_bit_identical_under_fault_replay() {
    for case in 0..40u64 {
        let mut rng = Pcg::new(21_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let dim = 1 + rng.below(16);
        let delay = rng.below(3) as u64;
        let plan = arb_plan(&mut rng, n, 30, case);
        let clock = FaultClock::new(plan);
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
        let sched = Schedule::with_seed(kind, n, case);
        for &shards in SHARDS {
            let tag = format!(
                "case {case}: {kind:?} n={n} dim={dim} delay={delay} \
                 shards={shards} plan={:?}",
                clock.plan
            );
            let mut seq = PushSumEngine::new(init.clone(), delay, false);
            let mut par = PushSumEngine::new(init.clone(), delay, false);
            for k in 0..30 {
                seq.step_exec(k, &sched, Some(&clock), ExecPolicy::Sequential);
                par.step_exec(k, &sched, Some(&clock), ExecPolicy::parallel(shards));
            }
            assert_engines_identical(&seq, &par, &tag);
            seq.drain();
            par.drain();
            assert_engines_identical(&seq, &par, &format!("{tag} (drained)"));
        }
    }
}

#[test]
fn prop_pooled_engine_bit_identical_across_thread_counts() {
    // The persistent pool's contract (PR 5): at fixed seed the pooled
    // engine matches the sequential engine bit-for-bit at ANY worker
    // count — {1, 2, 7} crossed with faults on/off and compression
    // on/off. Thread counts below, equal to, and above the shard count
    // all exercise the shard→worker pinning (j ≡ w mod W).
    use sgp::runtime::pool::Pool;
    use std::sync::Arc;
    for case in 0..18u64 {
        let mut rng = Pcg::new(25_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let dim = 1 + rng.below(16);
        let delay = rng.below(3) as u64;
        let faulty = case % 2 == 0;
        let spec = match case % 3 {
            0 => Compression::Identity,
            1 => Compression::TopK { den: 4 },
            _ => Compression::Qsgd { bits: 4 },
        };
        let plan = if faulty {
            arb_plan(&mut rng, n, 30, case).with_drop(0.15)
        } else {
            FaultPlan::lossless()
        };
        let clock = FaultClock::new(plan);
        let faults = if faulty { Some(&clock) } else { None };
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
        let sched = Schedule::with_seed(kind, n, case);
        let mut seq = PushSumEngine::new(init.clone(), delay, false);
        for k in 0..30 {
            seq.step_compressed(k, &sched, faults, ExecPolicy::Sequential, spec);
        }
        for threads in [1usize, 2, 7] {
            let tag = format!(
                "case {case}: {kind:?} n={n} dim={dim} delay={delay} \
                 faulty={faulty} {spec:?} threads={threads}"
            );
            let mut par = PushSumEngine::new(init.clone(), delay, false);
            par.set_pool(Some(Arc::new(Pool::new(threads))));
            for k in 0..30 {
                par.step_compressed(
                    k,
                    &sched,
                    faults,
                    ExecPolicy::parallel(5),
                    spec,
                );
            }
            assert_engines_identical(&seq, &par, &tag);
        }
    }
}

#[test]
fn prop_legacy_step_entrypoints_match_step_exec() {
    // step()/step_faulty() are thin wrappers over the sharded driver; the
    // wrappers and the explicit sequential policy must agree exactly.
    for case in 0..20u64 {
        let mut rng = Pcg::new(22_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let dim = 1 + rng.below(8);
        let plan = arb_plan(&mut rng, n, 20, case);
        let clock = FaultClock::new(plan);
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
        let sched = Schedule::with_seed(kind, n, case);
        let mut a = PushSumEngine::new(init.clone(), 1, false);
        let mut b = PushSumEngine::new(init, 1, false);
        for k in 0..20 {
            a.step_faulty(k, &sched, &clock);
            b.step_exec(k, &sched, Some(&clock), ExecPolicy::Sequential);
        }
        assert_engines_identical(&a, &b, &format!("case {case}"));
    }
}

#[test]
fn prop_sharded_timing_sim_bit_identical() {
    // The sharded arrival computation in the timing recursion merges
    // partial deadline vectors with f64::max — the clocks must be
    // bit-identical to the sequential fold for any shard count, with and
    // without faults. n = 256 crosses the sharding threshold.
    let n = 256;
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);
    let compute = ComputeModel::resnet50_dgx1();
    for &drop in &[0.0, 0.1] {
        let clock = FaultClock::new(
            FaultPlan::lossless()
                .with_drop(drop)
                .with_crash(7, 3, Some(9))
                .with_seed(3),
        );
        let mut seq = TimingSim::new(n, LinkModel::ethernet_10g());
        let mut par = TimingSim::new(n, LinkModel::ethernet_10g());
        par.set_shards(4);
        let mut rng = Pcg::new(11);
        for k in 0..12u64 {
            let comp = compute.sample_all(n, &mut rng);
            let pat = OwnedCommPattern::PushSum {
                schedule: sched.clone(),
                bytes: 1 << 20,
                tau: 1,
            };
            let ma = seq.advance_with_faults(&pat.borrowed(), &comp, Some(&clock));
            let mb = par.advance_with_faults(&pat.borrowed(), &comp, Some(&clock));
            assert_eq!(ma.to_bits(), mb.to_bits(), "drop={drop} k={k}");
            for (a, b) in seq.t.iter().zip(&par.t) {
                assert_eq!(a.to_bits(), b.to_bits(), "drop={drop} k={k}");
            }
        }
        // Clean advance too (no fault clock at all).
        let mut seq = TimingSim::new(n, LinkModel::ethernet_10g());
        let mut par = TimingSim::new(n, LinkModel::ethernet_10g());
        par.set_shards(4);
        let mut rng = Pcg::new(12);
        for k in 0..8u64 {
            let comp = compute.sample_all(n, &mut rng);
            let pat = CommPattern::PushSum { schedule: &sched, bytes: 1 << 20, tau: 0 };
            let ma = seq.advance(&pat, &comp);
            let mb = par.advance(&pat, &comp);
            assert_eq!(ma.to_bits(), mb.to_bits(), "clean k={k}");
        }
    }
}

#[test]
fn prop_compressed_harness_runs_identical_across_engines() {
    // The compress-sweep acceptance clause, end-to-end: a compressed run
    // through the full offline harness (coordinator protocol, gossip with
    // error-feedback residuals, byte-accurate timing) reports
    // bit-identical stats at shard counts {1, 2, 7} — with and without a
    // fault plan in the mix.
    use sgp::faults::harness::{run_quadratic, FaultRunConfig};
    for case in 0..4u64 {
        let mut rng = Pcg::new(24_000 + case);
        let algo = ["sgp", "osgp", "dpsgd", "dasgd"][rng.below(4)];
        let spec = if case % 2 == 0 {
            Compression::TopK { den: 16 }
        } else {
            Compression::Qsgd { bits: 4 }
        };
        let plan = if rng.f64() < 0.5 {
            arb_plan(&mut rng, 8, 40, case).with_drop(0.1)
        } else {
            FaultPlan::lossless()
        };
        let seq_cfg = FaultRunConfig {
            n: 8,
            iters: 40,
            compress: spec,
            heterogeneity: 0.5,
            ..Default::default()
        };
        let a = run_quadratic(algo, &seq_cfg, &plan).unwrap();
        for shards in [2usize, 7] {
            let par_cfg = FaultRunConfig {
                exec: ExecPolicy::parallel(shards),
                ..seq_cfg.clone()
            };
            let b = run_quadratic(algo, &par_cfg, &plan).unwrap();
            assert_eq!(
                a.final_err.to_bits(),
                b.final_err.to_bits(),
                "case {case}: {algo} {spec:?} shards={shards} final_err"
            );
            assert_eq!(
                a.consensus.to_bits(),
                b.consensus.to_bits(),
                "case {case}: {algo} {spec:?} shards={shards} consensus"
            );
            assert_eq!(
                a.makespan.to_bits(),
                b.makespan.to_bits(),
                "case {case}: {algo} {spec:?} shards={shards} makespan"
            );
        }
    }
}

#[test]
fn prop_harness_runs_identical_across_engines() {
    // End-to-end: the offline fault harness (coordinator round protocol,
    // gossip, timing) must report bit-identical stats whichever engine
    // executes it.
    use sgp::faults::harness::{run_quadratic, FaultRunConfig};
    for case in 0..4u64 {
        let mut rng = Pcg::new(23_000 + case);
        let algo = ["sgp", "osgp", "dpsgd", "dasgd"][rng.below(4)];
        let plan = arb_plan(&mut rng, 8, 40, case).with_drop(0.1);
        let seq_cfg = FaultRunConfig { n: 8, iters: 40, ..Default::default() };
        let par_cfg = FaultRunConfig {
            exec: ExecPolicy::parallel(7),
            ..seq_cfg.clone()
        };
        let a = run_quadratic(algo, &seq_cfg, &plan).unwrap();
        let b = run_quadratic(algo, &par_cfg, &plan).unwrap();
        assert_eq!(
            a.final_err.to_bits(),
            b.final_err.to_bits(),
            "case {case}: {algo} final_err"
        );
        assert_eq!(
            a.consensus.to_bits(),
            b.consensus.to_bits(),
            "case {case}: {algo} consensus"
        );
        assert_eq!(
            a.makespan.to_bits(),
            b.makespan.to_bits(),
            "case {case}: {algo} makespan"
        );
    }
}

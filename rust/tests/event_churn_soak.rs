//! Churn soak for the event-driven execution path: a long run under
//! repeated crash/rejoin cycles plus message loss (with rescue), checking
//! the two invariants that must survive arbitrary churn:
//!
//! 1. **Mass conservation** — Σᵢ wᵢ, counting in-flight mail and the
//!    drop ledger ([`PushSumEngine::total_mass_with_losses`]), stays at
//!    `n` to 1e-9 relative error throughout the run. Event-mode parking
//!    (mail addressed to a crashed node) must hold mass, not leak it.
//! 2. **Consensus progress** — the mean push-sum distance
//!    ‖zᵢ − x̄‖₂ shrinks by a large factor despite nodes dropping out
//!    and rejoining with stale state.
//!
//! Every crash/rejoin boundary also bumps the membership epoch, so this
//! run drives the memoized peer table ([`sgp::topology::PeerMemo`])
//! through real invalidation cycles rather than the synthetic ones in the
//! unit tests.
//!
//! The CI-sized variant runs by default; the full soak (10k nodes,
//! 5k ticks) is `#[ignore]`d — run it with
//! `cargo test --release --test event_churn_soak -- --ignored`.

use sgp::faults::{FaultClock, FaultPlan};
use sgp::gossip::{ExecPolicy, PushSumEngine};
use sgp::rng::Pcg;
use sgp::topology::{Schedule, TopologyKind};

/// Build a churn plan: `cycles` staggered crash/rejoin windows spread over
/// the run (every other one permanent-until-rejoin-window-ends), plus 5%
/// message loss with rescue so dropped mass flows back to senders.
fn churn_plan(n: usize, ticks: u64, cycles: usize, seed: u64) -> FaultPlan {
    let mut plan = FaultPlan::lossless()
        .with_drop(0.05)
        .with_rescue(true)
        .with_seed(seed);
    let span = ticks / (cycles as u64 + 1);
    for c in 0..cycles {
        let node = (c * 7919) % n; // co-prime stride: spread over the ring
        let at = span * (c as u64 + 1);
        let down_for = span / 2 + (c as u64 % 5);
        plan = plan.with_crash(node, at, Some(at + down_for.max(1)));
    }
    plan
}

/// Run the soak at the given scale and check both invariants.
fn soak(n: usize, dim: usize, ticks: u64, cycles: usize, check_every: u64) {
    let mut rng = Pcg::new(0xC0FFEE ^ ticks);
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);
    let clock = FaultClock::new(churn_plan(n, ticks, cycles, 11));

    let mut eng = PushSumEngine::new(init, 1, false);
    let (_, w0) = eng.total_mass_with_losses();
    let (d0, _, _) = eng.consensus_distance();
    assert!(d0 > 0.0, "gaussian init must start spread out");

    let w_tol = 1e-9 * n as f64;
    for k in 0..ticks {
        eng.step_exec(k, &sched, Some(&clock), ExecPolicy::Event);
        if k % check_every == 0 || k + 1 == ticks {
            let (_, wm) = eng.total_mass_with_losses();
            assert!(
                (wm - w0).abs() < w_tol,
                "Σw drifted at k={k}: {wm} vs {w0} (tol {w_tol}) — event \
                 parking or the drop ledger is leaking mass"
            );
        }
    }
    assert!(eng.drop_count == 0, "rescue must re-absorb every drop");
    assert!(eng.rescue_count > 0, "5% loss over {ticks} ticks must drop mail");

    // Force-deliver whatever is still in flight (including mail parked for
    // any node that never rejoined) and re-check the ledger one last time.
    eng.drain();
    let (_, wm) = eng.total_mass_with_losses();
    assert!((wm - w0).abs() < w_tol, "Σw drifted after drain: {wm} vs {w0}");

    let (d1, _, _) = eng.consensus_distance();
    assert!(
        d1 < d0 * 1e-2,
        "consensus stalled under churn: mean distance {d0} → {d1}"
    );
}

/// CI-sized churn soak: small enough for the default test run.
#[test]
fn churn_soak_fast() {
    soak(200, 8, 300, 6, 1);
}

/// The full soak from ISSUE 8: 10k nodes, 5k ticks, heavy churn. Too slow
/// for default CI — run explicitly with `-- --ignored`.
#[test]
#[ignore = "long soak: run with --release -- --ignored"]
fn churn_soak_10k_nodes_5k_ticks() {
    soak(10_000, 16, 5_000, 40, 25);
}

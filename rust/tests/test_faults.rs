//! End-to-end fault & churn regression tests — all offline (synthetic
//! quadratic gradients through the registered strategies), so they run in
//! tier-1 everywhere the crate builds.
//!
//! The acceptance anchor for the fault subsystem is the paper's Section-1
//! claim: gossip degrades gracefully under message loss while exact
//! averaging pays for its barrier — locked in by
//! `sgp_degrades_gracefully_while_allreduce_inflates`. Rescue mode
//! (senders re-absorb undelivered mass, push-sum's local loss-recovery;
//! DESIGN.md §Faults) is the loss-tolerant configuration the sweep
//! defaults to; `naive_loss_destabilizes_but_rescue_recovers` pins down
//! *why* it is needed.

use sgp::algorithms::{self, AlgoParams, DistributedAlgorithm, RoundCtx};
use sgp::faults::harness::{run_quadratic, FaultRunConfig, FaultRunStats};
use sgp::faults::{FaultClock, FaultPlan};
use sgp::gossip::PushSumEngine;
use sgp::net::LinkModel;
use sgp::optim::OptimKind;
use sgp::rng::Pcg;
use sgp::topology::{Schedule, TopologyKind};

fn cfg() -> FaultRunConfig {
    FaultRunConfig::default() // n=16, 150 iters, 10 GbE, ResNet-scale msgs
}

fn run(algo: &str, plan: &FaultPlan) -> FaultRunStats {
    run_quadratic(algo, &cfg(), plan).unwrap()
}

#[test]
fn sgp_degrades_gracefully_while_allreduce_inflates() {
    // The ordering the whole subsystem exists to demonstrate, at the
    // acceptance threshold (≥5% message drop):
    //  * SGP (in the sweep's default loss-tolerant rescue configuration)
    //    keeps a flat makespan — a dropped message never stalls its
    //    destination — and its accuracy/consensus degrade gracefully;
    //  * AR-SGD still converges exactly but its makespan inflates — every
    //    round waits for the unluckiest link's retransmissions.
    let clean = FaultPlan::lossless().with_seed(3);
    let lossy = FaultPlan::lossless().with_drop(0.05).with_rescue(true).with_seed(3);

    let sgp0 = run("sgp", &clean);
    let sgp5 = run("sgp", &lossy);
    let ar0 = run("ar-sgd", &clean);
    let ar5 = run("ar-sgd", &lossy);

    let sgp_slowdown = sgp5.makespan / sgp0.makespan;
    let ar_slowdown = ar5.makespan / ar0.makespan;
    assert!(
        sgp_slowdown < 1.05,
        "SGP makespan must stay flat under 5% loss: {sgp_slowdown:.3}×"
    );
    assert!(
        ar_slowdown > 1.2,
        "AR-SGD makespan must inflate under 5% loss: {ar_slowdown:.3}×"
    );
    assert!(
        ar_slowdown - 1.0 > 3.0 * (sgp_slowdown - 1.0).max(0.0) + 0.1,
        "the ordering the paper claims: AR {ar_slowdown:.3}× vs SGP {sgp_slowdown:.3}×"
    );

    // Graceful semantic degradation: SGP still lands near the optimum with
    // a consensus distance close to the lossless equilibrium (which sits
    // at O(lr · gradient heterogeneity), not zero).
    assert!(sgp5.final_err < 0.2, "SGP error under loss: {}", sgp5.final_err);
    assert!(
        sgp5.consensus < 2.0 * sgp0.consensus + 0.1,
        "no consensus blow-up: {} vs {}",
        sgp0.consensus,
        sgp5.consensus
    );
}

#[test]
fn naive_loss_destabilizes_but_rescue_recovers() {
    // The finding DESIGN.md §Faults documents: without rescue, a lost
    // message removes (x, w) mass permanently; the push-sum weight of an
    // unlucky node random-walks toward zero and the *effective* step size
    // of the gradient applied at z = x/w is lr/w — the run destabilizes.
    // Rescue (the sender re-absorbs what it could not deliver) restores
    // exact column-stochasticity and the run tracks the lossless one.
    let naive = run("sgp", &FaultPlan::lossless().with_drop(0.15).with_seed(13));
    let rescued = run(
        "sgp",
        &FaultPlan::lossless().with_drop(0.15).with_seed(13).with_rescue(true),
    );
    let lossless = run("sgp", &FaultPlan::lossless().with_seed(13));
    assert!(
        rescued.final_err < 0.2,
        "rescued SGP must stay near the optimum: {}",
        rescued.final_err
    );
    assert!(
        rescued.final_err < lossless.final_err + 0.15,
        "rescued {} should track lossless {}",
        rescued.final_err,
        lossless.final_err
    );
    // NaN-safe: a destabilized run may overflow to inf/NaN — both count.
    assert!(
        !(naive.final_err <= 5.0 * rescued.final_err),
        "naive loss must be way off (or diverged): naive {} vs rescued {}",
        naive.final_err,
        rescued.final_err
    );
}

#[test]
fn sweep_top_fault_level_stays_graceful_with_rescue() {
    // Even at the sweep's top fault level (20% drop) the default (rescue)
    // configuration must not collapse.
    let s = run(
        "sgp",
        &FaultPlan::lossless().with_drop(0.2).with_rescue(true).with_seed(7),
    );
    assert!(s.final_err < 0.3, "err {}", s.final_err);
    assert!(s.consensus < 1.0, "consensus {}", s.consensus);
}

#[test]
fn pushsum_weight_absorbs_loss_where_biased_averaging_drifts() {
    // Why column-stochasticity tolerates loss: a dropped message removes
    // numerator AND weight together, so the de-biased consensus stays near
    // the true average. The biased engine (w ≡ 1 — the same ablation that
    // models D-PSGD's weightless symmetric averaging) has nothing to
    // absorb the loss and its consensus value drifts far from the average.
    let n = 8;
    let d = 8;
    let mut rng = Pcg::new(14);
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
    let mut avg = vec![0.0f64; d];
    for v in &init {
        for (a, b) in avg.iter_mut().zip(v) {
            *a += *b as f64 / n as f64;
        }
    }
    let clock = FaultClock::new(FaultPlan::lossless().with_drop(0.1).with_seed(2));
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);
    let mut unbiased = PushSumEngine::new(init.clone(), 0, false);
    let mut biased = PushSumEngine::new(init, 0, true);
    for k in 0..120 {
        // Identical deterministic drop history for both engines.
        unbiased.step_faulty(k, &sched, &clock);
        biased.step_faulty(k, &sched, &clock);
    }
    unbiased.drain();
    biased.drain();
    let dev = |eng: &PushSumEngine| {
        let mut m = vec![0.0f64; d];
        for st in &eng.states {
            let z = st.debiased();
            for (a, b) in m.iter_mut().zip(&z) {
                *a += *b as f64 / n as f64;
            }
        }
        m.iter()
            .zip(&avg)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    };
    let du = dev(&unbiased);
    let db = dev(&biased);
    assert!(du < 0.5, "unbiased consensus must stay near the average: {du}");
    assert!(
        db > 2.0 * du,
        "biased averaging must drift much further: {db} vs {du}"
    );
}

#[test]
fn crash_and_rejoin_still_converges_for_every_strategy() {
    // Churn composes with every registered strategy: crash one node a
    // third of the way in, rejoin it from its checkpoint later; everything
    // still optimizes the quadratic.
    let plan = FaultPlan::lossless()
        .with_crash(3, 50, Some(100))
        .with_seed(17);
    for algo in ["sgp", "sgp-2p", "osgp", "dpsgd", "adpsgd", "dasgd", "ar-sgd"] {
        let s = run_quadratic(algo, &cfg(), &plan).unwrap();
        assert!(
            s.final_err < 0.6,
            "{algo} under crash/rejoin: err {}",
            s.final_err
        );
        assert!(s.consensus < 1.0, "{algo}: consensus {}", s.consensus);
    }
}

#[test]
fn permanent_leave_excludes_the_node_from_the_consensus_model() {
    let plan = FaultPlan::lossless().with_crash(5, 30, None).with_seed(19);
    let s = run("sgp", &plan);
    // Survivors converge to the survivors' optimum, which sits
    // ‖c̄ − c₅‖ / (n − 1) away from the full optimum — bounded drift, and
    // the departed node's frozen checkpoint is excluded from the reported
    // statistics.
    assert!(s.final_err < 0.6, "err {}", s.final_err);
    assert!(s.consensus < 1.0, "consensus {}", s.consensus);
}

#[test]
fn crashed_member_stalls_the_collective_but_not_the_gossip() {
    let plan = FaultPlan::lossless().with_crash(2, 40, Some(80)).with_seed(23);
    let clean = FaultPlan::lossless().with_seed(23);
    let ar = run("ar-sgd", &plan);
    let ar0 = run("ar-sgd", &clean);
    let sgp = run("sgp", &plan);
    let sgp0 = run("sgp", &clean);
    // AR pays detection timeouts (abort + re-form) on crash and rejoin.
    assert!(
        ar.makespan > ar0.makespan + 1.5 * plan.timeout_s,
        "AR must pay the churn timeouts: {} vs {}",
        ar.makespan,
        ar0.makespan
    );
    // Gossip just re-indexes over survivors: makespan may even shrink.
    assert!(
        sgp.makespan < sgp0.makespan * 1.05,
        "SGP under churn: {} vs {}",
        sgp.makespan,
        sgp0.makespan
    );
}

#[test]
fn prop_crash_then_fire_never_panics_across_strategies() {
    // Crash-then-fire: random churn plans — permanent leaves included,
    // crashes landing at any round, several per run — drive every
    // registered strategy through the full harness. The historical
    // panics this pins down: `mixing_matrix_among`'s "peer must be
    // alive" expect and AD-PSGD's "event node is alive" expect, both
    // reachable in spirit when a schedule round or queued event
    // references a departed node. Survivor metrics must come back
    // finite (or the run is allowed to have diverged numerically — but
    // never to have panicked), and the survivor mixing matrix must stay
    // column-stochastic at every churn level.
    let algos = ["sgp", "sgp-2p", "osgp", "dpsgd", "adpsgd", "dasgd", "ar-sgd"];
    for case in 0..24u64 {
        let mut rng = Pcg::new(31_000 + case);
        let n = [4usize, 8, 13][rng.below(3)];
        let iters = 40u64;
        let mut plan = FaultPlan::lossless()
            .with_drop(rng.f64() * 0.2)
            .with_rescue(rng.f64() < 0.5)
            .with_seed(case);
        for _ in 0..1 + rng.below(3) {
            let node = rng.below(n);
            let at = rng.next_u64() % iters;
            // Half the crashes are permanent leaves — the departed-node
            // case the expects used to be reachable for.
            let rejoin = (rng.f64() < 0.5).then(|| at + 1 + rng.next_u64() % iters);
            plan = plan.with_crash(node, at, rejoin);
        }
        let algo = algos[rng.below(algos.len())];
        let cfg = FaultRunConfig { n, iters, dim: 8, ..FaultRunConfig::default() };
        let s = run_quadratic(algo, &cfg, &plan)
            .unwrap_or_else(|e| panic!("case {case}: {algo} errored: {e}"));
        assert!(s.makespan.is_finite(), "case {case}: {algo} makespan");

        // The survivor mixing matrix stays column-stochastic at every
        // round of the same churn history (the topology half of the fix).
        let clock = FaultClock::new(plan);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in (0..iters).step_by(7) {
            let alive = clock.alive(n, k);
            if alive.is_empty() {
                continue;
            }
            let p = sched.mixing_matrix_among(k, &alive);
            for c in 0..alive.len() {
                let sum: f64 = (0..alive.len()).map(|r| p.at(r, c)).sum();
                assert!(
                    (sum - 1.0).abs() < 1e-12,
                    "case {case} k={k}: column {c} sums to {sum}"
                );
            }
        }
    }
}

#[test]
fn membership_hook_default_is_noop_and_trait_object_safe() {
    // The default-implemented hook must be callable through a boxed trait
    // object without the strategy opting in.
    let p = AlgoParams::new(4, vec![0.0f32; 8], OptimKind::Sgd);
    let mut alg: Box<dyn DistributedAlgorithm> =
        algorithms::build("sgp", &p).unwrap();
    let clock = FaultClock::new(FaultPlan::lossless().with_crash(1, 2, Some(4)));
    for k in 0..6u64 {
        for ev in clock.events_at(k) {
            alg.on_membership_change(&ev);
        }
        let comp = vec![0.1; 4];
        let link = LinkModel::ethernet_10g();
        let ctx = RoundCtx::new(k, &comp, 32, &link).with_faults(&clock);
        alg.communicate(&ctx);
    }
    alg.drain();
    assert_eq!(alg.n(), 4);
}

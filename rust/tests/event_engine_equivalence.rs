//! The event engine's dense-identity contract, property-tested.
//!
//! Two layers, two batteries:
//!
//! 1. [`sgp::gossip::ExecPolicy::Event`] on the dense engine must be
//!    **bit-identical** to the sequential and pooled engines — states,
//!    mailboxes, ledger, counters, consensus — for random topologies ×
//!    fault plans × compression specs × delays, including the τ ≥ 2
//!    regime where the swap-remove drain permutes not-yet-due survivors
//!    (the ordering trap that forces notifications-only queues).
//!
//! 2. The sparse [`sgp::gossip::EventEngine`] must match a dense engine
//!    started from the fully-materialized initial state: bit-identical
//!    per-node states while on the fast path, through the dense fall-off,
//!    and across mid-run regime changes (compression switching on).
//!
//! Same generator style as `prop_invariants.rs`: the offline build has no
//! proptest, so cases are drawn from seeded [`Pcg`] streams and the
//! failing case's seed is printed in the assert message.

use sgp::faults::{FaultClock, FaultPlan};
use sgp::gossip::{Compression, EventEngine, ExecPolicy, PushSumEngine};
use sgp::rng::Pcg;
use sgp::topology::{Schedule, TopologyKind};

const KINDS: &[TopologyKind] = &[
    TopologyKind::OnePeerExp,
    TopologyKind::TwoPeerExp,
    TopologyKind::Complete,
    TopologyKind::CompleteCycling,
    TopologyKind::RandomExp,
    TopologyKind::RandomAny,
    TopologyKind::Ring,
    TopologyKind::BipartiteExp,
];

/// Unit-permutation schedules — the sparse fast path's domain.
const PERM_KINDS: &[TopologyKind] = &[
    TopologyKind::OnePeerExp,
    TopologyKind::Ring,
    TopologyKind::CompleteCycling,
];

const SPECS: &[Compression] = &[
    Compression::Identity,
    Compression::TopK { den: 8 },
    Compression::Qsgd { bits: 4 },
];

fn arb_n(rng: &mut Pcg) -> usize {
    [2, 3, 5, 8, 13, 16, 32, 256][rng.below(8)]
}

/// Random fault plan: drop rate, maybe rescue, up to two crashes
/// (rejoining or permanent).
fn arb_plan(rng: &mut Pcg, n: usize, horizon: u64, seed: u64) -> FaultPlan {
    let mut plan = FaultPlan::lossless()
        .with_drop(rng.f64() * 0.3)
        .with_rescue(rng.f64() < 0.5)
        .with_seed(seed);
    for _ in 0..rng.below(3) {
        let node = rng.below(n);
        let at = rng.next_u64() % horizon.max(1);
        let rejoin = if rng.f64() < 0.5 {
            Some(at + 1 + rng.next_u64() % horizon.max(1))
        } else {
            None
        };
        plan = plan.with_crash(node, at, rejoin);
    }
    plan
}

/// Assert two dense engines hold exactly the same bits everywhere the
/// contract covers.
fn assert_engines_identical(seq: &PushSumEngine, evt: &PushSumEngine, tag: &str) {
    for (i, (a, b)) in seq.states.iter().zip(&evt.states).enumerate() {
        assert_eq!(a.x, b.x, "{tag}: node {i} numerator diverged");
        assert_eq!(
            a.w.to_bits(),
            b.w.to_bits(),
            "{tag}: node {i} push-sum weight diverged"
        );
    }
    assert_eq!(seq.in_flight(), evt.in_flight(), "{tag}: in-flight count");
    assert_eq!(seq.sent_count, evt.sent_count, "{tag}: sent counter");
    assert_eq!(seq.drop_count, evt.drop_count, "{tag}: drop counter");
    assert_eq!(seq.rescue_count, evt.rescue_count, "{tag}: rescue counter");
    let (dxa, dwa) = seq.dropped_mass();
    let (dxb, dwb) = evt.dropped_mass();
    assert_eq!(dwa.to_bits(), dwb.to_bits(), "{tag}: dropped w ledger");
    for (a, b) in dxa.iter().zip(dxb) {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: dropped x ledger");
    }
    let (ca, cb) = (seq.consensus_distance(), evt.consensus_distance());
    assert_eq!(ca.0.to_bits(), cb.0.to_bits(), "{tag}: consensus mean");
    assert_eq!(ca.1.to_bits(), cb.1.to_bits(), "{tag}: consensus min");
    assert_eq!(ca.2.to_bits(), cb.2.to_bits(), "{tag}: consensus max");
}

/// Mass-ledger balance: states + in-flight + drop ledger + banks must
/// still account for every unit of the initial mass (same tolerances as
/// `prop_invariants.rs`: w is exact f64 arithmetic, x crosses f32
/// compression rounding).
fn assert_mass_balanced(eng: &PushSumEngine, x0: &[f64], w0: f64, tag: &str) {
    let (xm, wm) = eng.total_mass_with_losses();
    assert!((wm - w0).abs() < 1e-9, "{tag}: w mass drifted ({wm} vs {w0})");
    for (d, (got, want)) in xm.iter().zip(x0).enumerate() {
        assert!(
            (got - want).abs() < 1e-2,
            "{tag}: x[{d}] mass drifted ({got} vs {want})"
        );
    }
}

#[test]
fn prop_event_policy_bit_identical_clean() {
    for case in 0..40u64 {
        let mut rng = Pcg::new(30_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let dim = 1 + rng.below(24);
        let delay = rng.below(4) as u64;
        let biased = rng.f64() < 0.2;
        let spec = SPECS[rng.below(SPECS.len())];
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
        let sched = Schedule::with_seed(kind, n, case);
        let tag = format!(
            "case {case}: {kind:?} n={n} dim={dim} delay={delay} \
             biased={biased} {spec:?}"
        );
        let mut seq = PushSumEngine::new(init.clone(), delay, biased);
        let mut evt = PushSumEngine::new(init.clone(), delay, biased);
        let (x0, w0) = evt.total_mass_with_losses();
        for k in 0..25 {
            seq.step_compressed(k, &sched, None, ExecPolicy::Sequential, spec);
            evt.step_compressed(k, &sched, None, ExecPolicy::Event, spec);
        }
        assert_engines_identical(&seq, &evt, &tag);
        if !biased {
            assert_mass_balanced(&evt, &x0, w0, &tag);
        }
        seq.drain();
        evt.drain();
        assert_engines_identical(&seq, &evt, &format!("{tag} (drained)"));
    }
}

#[test]
fn prop_event_policy_bit_identical_under_fault_replay() {
    for case in 0..40u64 {
        let mut rng = Pcg::new(31_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let dim = 1 + rng.below(16);
        let delay = rng.below(3) as u64;
        let spec = SPECS[rng.below(SPECS.len())];
        let plan = arb_plan(&mut rng, n, 30, case);
        let clock = FaultClock::new(plan);
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
        let sched = Schedule::with_seed(kind, n, case);
        let tag = format!(
            "case {case}: {kind:?} n={n} dim={dim} delay={delay} {spec:?} \
             plan={:?}",
            clock.plan
        );
        let mut seq = PushSumEngine::new(init.clone(), delay, false);
        let mut evt = PushSumEngine::new(init.clone(), delay, false);
        let (x0, w0) = evt.total_mass_with_losses();
        for k in 0..30 {
            seq.step_compressed(k, &sched, Some(&clock), ExecPolicy::Sequential, spec);
            evt.step_compressed(k, &sched, Some(&clock), ExecPolicy::Event, spec);
        }
        assert_engines_identical(&seq, &evt, &tag);
        assert_mass_balanced(&evt, &x0, w0, &tag);
        seq.drain();
        evt.drain();
        assert_engines_identical(&seq, &evt, &format!("{tag} (drained)"));
        assert_mass_balanced(&evt, &x0, w0, &format!("{tag} (drained)"));
    }
}

#[test]
fn prop_event_policy_bit_identical_to_pooled() {
    // Event vs pooled {2, 7}: both must agree with each other (they each
    // agree with sequential by the other batteries, but testing the pair
    // directly keeps the diagnosis one hop when only one battery fails).
    for case in 0..20u64 {
        let mut rng = Pcg::new(32_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let dim = 1 + rng.below(16);
        let delay = rng.below(3) as u64;
        let spec = SPECS[rng.below(SPECS.len())];
        let faulty = case % 2 == 0;
        let plan = if faulty {
            arb_plan(&mut rng, n, 25, case).with_drop(0.15)
        } else {
            FaultPlan::lossless()
        };
        let clock = FaultClock::new(plan);
        let faults = if faulty { Some(&clock) } else { None };
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
        let sched = Schedule::with_seed(kind, n, case);
        let mut evt = PushSumEngine::new(init.clone(), delay, false);
        for k in 0..25 {
            evt.step_compressed(k, &sched, faults, ExecPolicy::Event, spec);
        }
        for shards in [2usize, 7] {
            let tag = format!(
                "case {case}: {kind:?} n={n} dim={dim} delay={delay} \
                 faulty={faulty} {spec:?} shards={shards}"
            );
            let mut par = PushSumEngine::new(init.clone(), delay, false);
            for k in 0..25 {
                par.step_compressed(k, &sched, faults, ExecPolicy::parallel(shards), spec);
            }
            assert_engines_identical(&par, &evt, &tag);
        }
    }
}

#[test]
fn prop_mid_run_policy_switches_are_lossless() {
    // Alternating sequential/pooled/event rounds within one run must not
    // change a single bit: the arrival scheduler is seeded from the
    // in-flight mailboxes when event mode first engages, and keeps
    // tracking sends made under the other policies afterwards.
    for case in 0..20u64 {
        let mut rng = Pcg::new(33_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let dim = 1 + rng.below(12);
        let delay = 1 + rng.below(3) as u64; // delay ≥ 1: mail is in flight at the switch
        let spec = SPECS[rng.below(SPECS.len())];
        let plan = arb_plan(&mut rng, n, 30, case);
        let clock = FaultClock::new(plan);
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
        let sched = Schedule::with_seed(kind, n, case);
        let tag = format!("case {case}: {kind:?} n={n} dim={dim} delay={delay} {spec:?}");
        let mut seq = PushSumEngine::new(init.clone(), delay, false);
        let mut mix = PushSumEngine::new(init.clone(), delay, false);
        for k in 0..30 {
            seq.step_compressed(k, &sched, Some(&clock), ExecPolicy::Sequential, spec);
            let policy = match k % 3 {
                0 => ExecPolicy::Sequential,
                1 => ExecPolicy::Event,
                _ => ExecPolicy::parallel(2),
            };
            mix.step_compressed(k, &sched, Some(&clock), policy, spec);
        }
        assert_engines_identical(&seq, &mix, &tag);
        seq.drain();
        mix.drain();
        assert_engines_identical(&seq, &mix, &format!("{tag} (drained)"));
    }
}

/// Assert every logical node of the sparse engine matches the dense
/// engine's state bit-for-bit (cold nodes compare through the template).
fn assert_matches_dense(evt: &EventEngine, dense: &PushSumEngine, tag: &str) {
    for i in 0..evt.n() {
        let a = evt.node_state(i);
        let b = &dense.states[i];
        assert_eq!(a.x, b.x, "{tag}: node {i} numerator diverged");
        assert_eq!(
            a.w.to_bits(),
            b.w.to_bits(),
            "{tag}: node {i} push-sum weight diverged"
        );
    }
}

#[test]
fn prop_sparse_engine_matches_dense_on_permutation_schedules() {
    // The fast path itself: perturb a few nodes of the cold graph and
    // check every tick against a dense engine started from the identical
    // (materialized) initial state. The engine must *stay* sparse — these
    // schedules are unit permutations and the template is halving-safe.
    for case in 0..24u64 {
        let mut rng = Pcg::new(34_000 + case);
        let kind = PERM_KINDS[rng.below(PERM_KINDS.len())];
        // ≤ 3 seeds × 20 ticks activate at most 63 nodes (one new node per
        // hot node per tick), so even n = 64 keeps a cold remainder.
        let n = [64, 128, 256][rng.below(3)];
        let dim = 1 + rng.below(8);
        let template: Vec<f32> =
            (0..dim).map(|d| [0.0f32, 0.5, 1.25, -3.0][d % 4]).collect();
        let sched = Schedule::with_seed(kind, n, case);
        let tag = format!("case {case}: {kind:?} n={n} dim={dim}");

        let mut evt = EventEngine::with_template(template.clone(), n, 0, false);
        let mut init: Vec<Vec<f32>> = (0..n).map(|_| template.clone()).collect();
        for _ in 0..1 + rng.below(3) {
            let node = rng.below(n);
            let d = rng.below(dim);
            let v = rng.gaussian() as f32;
            evt.state_mut(node).x[d] = v;
            init[node][d] = v;
        }
        let mut dense = PushSumEngine::new(init, 0, false);
        for k in 0..20 {
            evt.step(k, &sched, None, Compression::Identity);
            dense.step_exec(k, &sched, None, ExecPolicy::Sequential);
            assert_matches_dense(&evt, &dense, &format!("{tag} k={k}"));
        }
        assert!(evt.is_sparse(), "{tag}: fast path must hold");
        assert!(
            evt.materialized() < n,
            "{tag}: some of the graph should have stayed cold"
        );
        // The sparse mass accountant agrees with the dense one to f64
        // rounding (the cold block is summed as one product).
        let (xa, wa) = evt.total_mass();
        let (xb, wb) = dense.total_mass();
        assert!((wa - wb).abs() <= 1e-9 * (n as f64), "{tag}: w mass");
        for (a, b) in xa.iter().zip(&xb) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0), "{tag}: x mass");
        }
    }
}

#[test]
fn prop_sparse_fall_off_is_seamless() {
    // Run sparse for a while, then change the regime mid-run (compression
    // on, or a non-permutation schedule tick) — the engine materializes
    // and every subsequent step must still match the dense reference
    // bit-for-bit.
    for case in 0..16u64 {
        let mut rng = Pcg::new(35_000 + case);
        let n = [16, 32, 64][rng.below(3)];
        let dim = 1 + rng.below(8);
        let spec = if case % 2 == 0 {
            Compression::TopK { den: 8 }
        } else {
            Compression::Qsgd { bits: 4 }
        };
        let template: Vec<f32> = (0..dim).map(|d| 0.25 * d as f32).collect();
        let sched = Schedule::with_seed(TopologyKind::OnePeerExp, n, case);
        let tag = format!("case {case}: n={n} dim={dim} {spec:?}");

        let mut evt = EventEngine::with_template(template.clone(), n, 0, false);
        let mut init: Vec<Vec<f32>> = (0..n).map(|_| template.clone()).collect();
        let node = rng.below(n);
        evt.state_mut(node).x[0] = 2.5;
        init[node][0] = 2.5;
        let mut dense = PushSumEngine::new(init, 0, false);
        for k in 0..10 {
            evt.step(k, &sched, None, Compression::Identity);
            dense.step_compressed(
                k,
                &sched,
                None,
                ExecPolicy::Sequential,
                Compression::Identity,
            );
        }
        assert!(evt.is_sparse(), "{tag}: still sparse before the switch");
        let sent_sparse = evt.sent_count();
        for k in 10..25 {
            evt.step(k, &sched, None, spec);
            dense.step_compressed(k, &sched, None, ExecPolicy::Sequential, spec);
            assert_matches_dense(&evt, &dense, &format!("{tag} k={k}"));
        }
        assert!(!evt.is_sparse(), "{tag}: compression must force the fall-off");
        assert_eq!(evt.materialized(), n, "{tag}");
        assert!(
            evt.sent_count() > sent_sparse,
            "{tag}: dense rounds keep counting sends"
        );
        evt.drain();
        dense.drain();
        assert_matches_dense(&evt, &dense, &format!("{tag} (drained)"));
    }
}

#[test]
fn sparse_from_init_is_the_dense_engine_under_event_policy() {
    // EventEngine::from_init is documented as exactly the dense engine
    // stepping under ExecPolicy::Event — heterogeneous init, faults and
    // compression included.
    let mut rng = Pcg::new(36_000);
    let n = 32;
    let dim = 6;
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
    let sched = Schedule::with_seed(TopologyKind::TwoPeerExp, n, 5);
    let clock = FaultClock::new(
        FaultPlan::lossless()
            .with_drop(0.1)
            .with_crash(3, 4, Some(9))
            .with_seed(7),
    );
    let spec = Compression::TopK { den: 8 };
    let mut evt = EventEngine::from_init(init.clone(), 1, false);
    assert!(!evt.is_sparse());
    assert_eq!(evt.materialized(), n);
    let mut dense = PushSumEngine::new(init, 1, false);
    for k in 0..20 {
        evt.step(k, &sched, Some(&clock), spec);
        dense.step_compressed(k, &sched, Some(&clock), ExecPolicy::Sequential, spec);
    }
    assert_matches_dense(&evt, &dense, "from_init");
    assert_eq!(evt.sent_count(), dense.sent_count, "from_init: sent counter");
    assert_eq!(evt.in_flight(), dense.in_flight(), "from_init: in flight");
    let (dxa, dwa) = evt.dropped_mass();
    let (dxb, dwb) = dense.dropped_mass();
    assert_eq!(dwa.to_bits(), dwb.to_bits(), "from_init: dropped w");
    for (a, b) in dxa.iter().zip(dxb) {
        assert_eq!(a.to_bits(), b.to_bits(), "from_init: dropped x");
    }
}

//! The deterministic interleaving checker — the dynamic leg of the
//! `repro audit` determinism story (ISSUE 9, ARCHITECTURE.md §8).
//!
//! The pool's bit-identity argument is *structural*: shard→worker pinning
//! (`j ≡ w mod W`) makes the engine output a pure function of the job
//! set, never of scheduling timing. The static audit cannot check that,
//! and the existing equivalence proptests only sample whatever wake
//! orders the OS happens to produce. This test closes the gap the way
//! loom would if it could be vendored: [`WakePlan`] forces the epoch
//! barrier's worker *start* order through seeded permutations (re-drawn
//! every epoch), and we assert (a) bit-identical engine output against
//! the sequential reference across ≥ 5 seeds × shards {1, 2, 7}, and
//! (b) no lost or double dispatch under any permutation.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use sgp::gossip::{ExecPolicy, PushSumEngine};
use sgp::rng::Pcg;
use sgp::runtime::pool::{Pool, WakePlan};
use sgp::topology::{Schedule, TopologyKind};

/// ≥ 5 seeded wake-order permutations (acceptance floor), spread wide.
const SEEDS: &[u64] = &[11, 23, 37, 51, 64, 907];
const SHARDS: &[usize] = &[1, 2, 7];

fn assert_states_identical(seq: &PushSumEngine, par: &PushSumEngine, tag: &str) {
    for (i, (a, b)) in seq.states.iter().zip(&par.states).enumerate() {
        assert_eq!(a.x, b.x, "{tag}: node {i} numerator diverged");
        assert_eq!(
            a.w.to_bits(),
            b.w.to_bits(),
            "{tag}: node {i} push-sum weight diverged"
        );
    }
    assert_eq!(seq.in_flight(), par.in_flight(), "{tag}: in-flight count");
}

#[test]
fn engine_bit_identical_under_permuted_wake_orders() {
    let n = 16;
    let dim = 32;
    let rounds = 40u64;
    let mut rng = Pcg::new(0xA001);
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
    let sched = Schedule::with_seed(TopologyKind::OnePeerExp, n, 5);

    let mut seq = PushSumEngine::new(init.clone(), 1, false);
    for k in 0..rounds {
        seq.step_exec(k, &sched, None, ExecPolicy::Sequential);
    }

    for &shards in SHARDS {
        for &seed in SEEDS {
            for threads in [2usize, 3, 5] {
                let tag = format!("shards={shards} wake_seed={seed} threads={threads}");
                let pool = Arc::new(Pool::new(threads));
                pool.set_wake_plan(Some(WakePlan::new(seed)));
                let mut par = PushSumEngine::new(init.clone(), 1, false);
                par.set_pool(Some(Arc::clone(&pool)));
                for k in 0..rounds {
                    par.step_exec(k, &sched, None, ExecPolicy::parallel(shards));
                }
                assert_states_identical(&seq, &par, &tag);
            }
        }
    }
}

#[test]
fn no_lost_or_double_dispatch_under_any_permutation() {
    // Exactly-once at the pool layer itself: every job of every round
    // runs once, whatever start order the plan forces, including worker
    // counts above and below the job count.
    for &seed in SEEDS {
        for threads in [1usize, 2, 3, 7] {
            let pool = Pool::new(threads);
            pool.set_wake_plan(Some(WakePlan::new(seed)));
            for jobs in [2usize, 3, 7, 16] {
                for round in 0..25 {
                    let counts: Vec<AtomicUsize> =
                        (0..jobs).map(|_| AtomicUsize::new(0)).collect();
                    pool.run(jobs, &|j| {
                        counts[j].fetch_add(1, Ordering::Relaxed);
                    });
                    for (j, c) in counts.iter().enumerate() {
                        assert_eq!(
                            c.load(Ordering::Relaxed),
                            1,
                            "seed {seed} threads {threads} jobs {jobs} \
                             round {round}: job {j} not exactly-once"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn drained_engine_matches_after_permuted_runs() {
    // τ = 2 keeps shares in flight across rounds, so the drain path (the
    // mailbox sweep after the last round) also runs under the plan.
    let n = 13;
    let dim = 8;
    let mut rng = Pcg::new(0xA002);
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(dim)).collect();
    let sched = Schedule::with_seed(TopologyKind::TwoPeerExp, n, 9);

    let mut seq = PushSumEngine::new(init.clone(), 2, false);
    for k in 0..30 {
        seq.step_exec(k, &sched, None, ExecPolicy::Sequential);
    }
    seq.drain();

    for &seed in SEEDS {
        let pool = Arc::new(Pool::new(3));
        pool.set_wake_plan(Some(WakePlan::new(seed)));
        let mut par = PushSumEngine::new(init.clone(), 2, false);
        par.set_pool(Some(Arc::clone(&pool)));
        for k in 0..30 {
            par.step_exec(k, &sched, None, ExecPolicy::parallel(7));
        }
        par.drain();
        assert_states_identical(&seq, &par, &format!("drained wake_seed={seed}"));
    }
}

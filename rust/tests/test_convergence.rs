//! Theorem 1 & 2 sanity tests: SGP on synthetic smooth objectives, pure
//! Rust (no artifacts needed). These check the *trends* the theory
//! guarantees — O(1/√(nK)) stationarity of the node-wise average and
//! vanishing consensus error — not the constants.

use sgp::gossip::PushSumEngine;
use sgp::rng::Pcg;
use sgp::topology::{Schedule, TopologyKind};

/// Run SGP on node-local least squares fᵢ(x)=½‖x−cᵢ‖² (global optimum =
/// mean of the cᵢ) with gradient noise; return (‖x̄−x*‖, consensus error).
fn run_sgp_quadratic(
    n: usize,
    iters: u64,
    tau: u64,
    biased: bool,
    noise: f32,
    seed: u64,
) -> (f64, f64) {
    let d = 16;
    let mut rng = Pcg::new(seed);
    let centers: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
    let mut opt = vec![0.0f64; d];
    for c in &centers {
        for (o, v) in opt.iter_mut().zip(c) {
            *o += *v as f64 / n as f64;
        }
    }
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
    let mut eng = PushSumEngine::new(init, tau, biased);
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);
    // Theorem 1 step size γ = √(n/K), clamped for stability at small K.
    let gamma = ((n as f64 / iters as f64).sqrt()).min(0.25) as f32;
    for k in 0..iters {
        for i in 0..n {
            let z = eng.states[i].debiased();
            for (j, x) in eng.states[i].x.iter_mut().enumerate() {
                let g = z[j] - centers[i][j] + noise * rng.gaussian() as f32;
                *x -= gamma * g;
            }
        }
        eng.step(k, &sched);
    }
    eng.drain();
    let mean = eng.mean_x();
    let err: f64 = mean
        .iter()
        .zip(&opt)
        .map(|(m, o)| {
            let e = *m as f64 - o;
            e * e
        })
        .sum::<f64>()
        .sqrt();
    (err, eng.consensus_distance().0)
}

#[test]
fn sgp_average_converges_to_stationary_point() {
    let (err, _) = run_sgp_quadratic(8, 2000, 0, false, 0.1, 1);
    assert!(err < 0.05, "‖x̄ − x*‖ = {err}");
}

#[test]
fn consensus_error_scales_with_step_size() {
    // Lemma 3 / Fig. 2: the consensus neighbourhood is ∝ γ. At the
    // Theorem-1 operating point γ = √(n/K), quadrupling K halves γ and
    // should (roughly) halve the consensus error.
    let (_, cons_short) = run_sgp_quadratic(8, 500, 0, false, 0.1, 2);
    let (_, cons_long) = run_sgp_quadratic(8, 8000, 0, false, 0.1, 2);
    assert!(
        cons_long < cons_short * 0.55,
        "consensus {cons_short} → {cons_long} did not shrink with γ"
    );
    assert!(cons_long < 0.25, "consensus error = {cons_long}");
}

#[test]
fn more_iterations_improve_stationarity() {
    // Theorem 1: error at the γ=√(n/K) operating point shrinks with K.
    let (err_short, _) = run_sgp_quadratic(8, 200, 0, false, 0.2, 3);
    let (err_long, _) = run_sgp_quadratic(8, 5000, 0, false, 0.2, 3);
    assert!(
        err_long < err_short * 0.6,
        "short={err_short} long={err_long}"
    );
}

#[test]
fn overlap_delays_still_converge() {
    // Theorem 1 holds under bounded delays (τ-OSGP).
    for tau in [1u64, 2, 3] {
        let (err, cons) = run_sgp_quadratic(8, 3000, tau, false, 0.1, 4);
        assert!(err < 0.15, "τ={tau}: err={err}");
        assert!(cons < 0.4, "τ={tau}: consensus={cons}");
    }
}

#[test]
fn biased_overlap_converges_to_wrong_point() {
    // Table 4's mechanism: dropping the push-sum weight biases the fixed
    // point; the unbiased variant must be strictly more accurate.
    let (err_unbiased, _) = run_sgp_quadratic(8, 3000, 1, false, 0.05, 5);
    let (err_biased, _) = run_sgp_quadratic(8, 3000, 1, true, 0.05, 5);
    assert!(
        err_biased > 2.0 * err_unbiased,
        "biased={err_biased} unbiased={err_unbiased}"
    );
}

#[test]
fn heterogeneous_noise_still_reaches_consensus() {
    // ζ² > 0 (different cᵢ per node) is the default above; crank noise.
    let (err, cons) = run_sgp_quadratic(16, 4000, 0, false, 0.5, 6);
    assert!(err < 0.3, "err={err}");
    assert!(cons < 0.5, "consensus={cons}");
}

#[test]
fn larger_networks_converge_too() {
    let (err, cons) = run_sgp_quadratic(32, 3000, 0, false, 0.1, 7);
    assert!(err < 0.2, "err={err}");
    assert!(cons < 0.6, "cons={cons}");
}

//! Property-style fuzzing of the deployment wire protocol (the
//! proptest idiom, hand-rolled on the repo's seeded `Pcg` since the
//! offline build vendors no fuzzing crate):
//!
//! * arbitrary frame sequences encode → split across arbitrary
//!   read-chunk boundaries → decode to the identical sequence;
//! * arbitrary single-bit corruption is *detected* (decode errors, never
//!   panics, never silently yields the original sequence);
//! * truncated streams decode a prefix and report the partial tail;
//! * the share codecs are exact on post-compression payloads
//!   (identity/top-k) and idempotent on arbitrary floats (qsgd).

use sgp::gossip::Compression;
use sgp::net::cluster::wire::{
    decode_share, encode_frame, encode_share, Assignment, DoneReport, Envelope, Frame,
    FrameReader, WireError, WireEvent,
};
use sgp::rng::Pcg;

fn random_scheme(rng: &mut Pcg) -> Compression {
    match rng.below(4) {
        0 => Compression::Identity,
        1 => Compression::TopK { den: 1 + rng.below(64) as u32 },
        2 => Compression::Qsgd { bits: 2 + rng.below(15) as u8 },
        _ => Compression::Identity,
    }
}

fn random_f32_vec(rng: &mut Pcg, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| match rng.below(8) {
            0 => 0.0,
            1 => -0.0,
            2 => f32::MIN_POSITIVE,
            _ => (rng.f32() - 0.5) * 2e3,
        })
        .collect()
}

fn random_frame(rng: &mut Pcg) -> Envelope {
    let sender = rng.next_u32();
    let round = rng.next_u64() >> 16;
    match rng.below(7) {
        0 => Envelope::control(sender, round, Frame::Join {
            listen_port: rng.next_u32() as u16,
        }),
        1 => {
            let scheme = random_scheme(rng);
            let peers = (0..rng.below(6))
                .map(|i| format!("10.0.0.{i}:{}", 4000 + rng.below(1000)))
                .collect();
            Envelope {
                sender,
                round,
                scheme,
                msg: Frame::Assign(Assignment {
                    rank: rng.next_u32() % 64,
                    world: 1 + rng.next_u32() % 64,
                    seed: rng.next_u64(),
                    rounds: rng.next_u64() >> 32,
                    cooldown: rng.next_u64() >> 40,
                    dim: rng.next_u32() % 4096,
                    lr: rng.f32(),
                    round_ms: rng.next_u32() % 1000,
                    round_timeout_ms: rng.next_u32() % 10_000,
                    scheme,
                    peers,
                }),
            }
        }
        2 => Envelope::control(sender, round, Frame::Heartbeat),
        3 => {
            let rank = rng.next_u32() % 64;
            let at = rng.next_u64() >> 32;
            let ev = match rng.below(3) {
                0 => WireEvent::Leave { rank, at },
                1 => WireEvent::Degraded { rank, at },
                _ => WireEvent::Recovered { rank, at },
            };
            Envelope::control(sender, round, Frame::Membership(ev))
        }
        4 => {
            let scheme = random_scheme(rng);
            let share = (0..rng.below(256)).map(|_| rng.next_u32() as u8).collect();
            Envelope {
                sender,
                round,
                scheme,
                msg: Frame::Push { w: rng.f64(), share },
            }
        }
        5 => Envelope::control(
            sender,
            round,
            Frame::Done(DoneReport {
                w: rng.f64() * 4.0,
                recv_w: rng.f64() * 8.0,
                sent_w: rng.f64() * 8.0,
                rescued_w: rng.f64(),
                rescues: rng.next_u32() % 100,
                timeouts: rng.next_u32() % 100,
                x: random_f32_vec(rng, rng.below(64)),
            }),
        ),
        _ => Envelope::control(sender, round, Frame::Shutdown),
    }
}

fn encode_stream(frames: &[Envelope]) -> Vec<u8> {
    let mut bytes = Vec::new();
    for f in frames {
        encode_frame(f, &mut bytes);
    }
    bytes
}

/// Feed `bytes` to a FrameReader in random chunks, draining frames as
/// they complete. Returns the decoded frames and the first error, if any.
fn decode_chunked(
    rng: &mut Pcg,
    bytes: &[u8],
) -> (Vec<Envelope>, Option<WireError>, FrameReader) {
    let mut fr = FrameReader::new();
    let mut out = Vec::new();
    let mut off = 0;
    while off < bytes.len() {
        let chunk = 1 + rng.below(97).min(bytes.len() - off - 1);
        fr.extend(&bytes[off..off + chunk]);
        off += chunk;
        loop {
            match fr.next_frame() {
                Ok(Some(env)) => out.push(env),
                Ok(None) => break,
                Err(e) => return (out, Some(e), fr),
            }
        }
    }
    (out, None, fr)
}

#[test]
fn arbitrary_frames_survive_arbitrary_chunk_boundaries() {
    for case in 0..30u64 {
        let mut rng = Pcg::with_stream(0xf7a3_0001, case);
        let frames: Vec<Envelope> = (0..1 + rng.below(40)).map(|_| random_frame(&mut rng)).collect();
        let bytes = encode_stream(&frames);
        let (decoded, err, fr) = decode_chunked(&mut rng, &bytes);
        assert!(err.is_none(), "case {case}: unexpected error {err:?}");
        assert_eq!(decoded, frames, "case {case}");
        fr.finish().expect("no partial frame at clean end of stream");
    }
}

#[test]
fn single_bit_corruption_is_always_detected_and_never_panics() {
    let mut rng = Pcg::with_stream(0xf7a3_0002, 0);
    let frames: Vec<Envelope> = (0..6).map(|_| random_frame(&mut rng)).collect();
    let bytes = encode_stream(&frames);
    // Every byte, one flipped bit (rotating through bit positions).
    for (i, _) in bytes.iter().enumerate() {
        let mut bad = bytes.clone();
        bad[i] ^= 1 << (i % 8);
        let (decoded, err, fr) = decode_chunked(&mut rng, &bad);
        let clean = err.is_none() && fr.finish().is_ok() && decoded == frames;
        assert!(
            !clean,
            "flipping bit {} of byte {i} went completely undetected",
            i % 8
        );
    }
}

#[test]
fn truncated_streams_decode_a_prefix_and_flag_the_partial_tail() {
    let mut rng = Pcg::with_stream(0xf7a3_0003, 0);
    let frames: Vec<Envelope> = (0..5).map(|_| random_frame(&mut rng)).collect();
    let bytes = encode_stream(&frames);
    for cut in 0..bytes.len() {
        let mut fr = FrameReader::new();
        fr.extend(&bytes[..cut]);
        let mut decoded = Vec::new();
        loop {
            match fr.next_frame() {
                Ok(Some(env)) => decoded.push(env),
                Ok(None) => break,
                Err(e) => panic!("cut {cut}: truncation must starve, not error ({e})"),
            }
        }
        assert!(decoded.len() <= frames.len());
        assert_eq!(&frames[..decoded.len()], &decoded[..], "cut {cut}: prefix mismatch");
        if fr.buffered() > 0 {
            assert!(
                matches!(fr.finish(), Err(WireError::TrailingBytes(_))),
                "cut {cut}: partial tail not flagged"
            );
        }
    }
}

#[test]
fn identity_and_topk_share_codecs_are_bit_exact() {
    for case in 0..40u64 {
        let mut rng = Pcg::with_stream(0xf7a3_0004, case);
        let dim = 1 + rng.below(300);

        let dense = random_f32_vec(&mut rng, dim);
        let mut buf = Vec::new();
        encode_share(Compression::Identity, &dense, &mut buf);
        let back = decode_share(Compression::Identity, dim, &buf).unwrap();
        assert!(dense.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));

        // Top-k payloads are mostly-zero vectors (what `apply` emits).
        let spec = Compression::TopK { den: 1 + rng.below(16) as u32 };
        let mut sparse = vec![0.0f32; dim];
        for _ in 0..rng.below(dim + 1) {
            let i = rng.below(dim);
            sparse[i] = (rng.f32() - 0.5) * 100.0;
        }
        if dim > 1 {
            sparse[rng.below(dim)] = -0.0; // explicit negative zero must survive
        }
        encode_share(spec, &sparse, &mut buf);
        let back = decode_share(spec, dim, &buf).unwrap();
        assert!(
            sparse.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()),
            "case {case}: top-k share not bit-exact"
        );
    }
}

#[test]
fn qsgd_share_codec_is_idempotent_on_arbitrary_floats() {
    // QSGD is lossy on arbitrary input, but decode∘encode must be a
    // projection: once a vector is on the quantization grid, another
    // trip through the codec is the identity.
    for case in 0..40u64 {
        let mut rng = Pcg::with_stream(0xf7a3_0005, case);
        let dim = 1 + rng.below(200);
        let bits = 2 + rng.below(15) as u8;
        let spec = Compression::Qsgd { bits };
        let x = random_f32_vec(&mut rng, dim);

        let mut b1 = Vec::new();
        encode_share(spec, &x, &mut b1);
        let y = decode_share(spec, dim, &b1).unwrap();
        let mut b2 = Vec::new();
        encode_share(spec, &y, &mut b2);
        let z = decode_share(spec, dim, &b2).unwrap();
        assert!(
            y.iter().zip(&z).all(|(a, b)| a.to_bits() == b.to_bits()),
            "case {case}: qsgd decode∘encode is not idempotent"
        );
        assert_eq!(b1.len(), b2.len(), "case {case}: byte footprint changed");
    }
}

#[test]
fn corrupted_share_payloads_error_out_cleanly() {
    let mut rng = Pcg::with_stream(0xf7a3_0006, 0);
    let dim = 64;
    for spec in [
        Compression::Identity,
        Compression::TopK { den: 4 },
        Compression::Qsgd { bits: 6 },
    ] {
        let x = random_f32_vec(&mut rng, dim);
        let mut buf = Vec::new();
        encode_share(spec, &x, &mut buf);
        // Truncations: must error (or, if still decodable, stay in-bounds).
        for cut in 0..buf.len() {
            let _ = decode_share(spec, dim, &buf[..cut]); // must not panic
        }
        // Random byte corruption: must not panic; result is either an
        // error or a dim-length vector (bounds always hold).
        for _ in 0..200 {
            let mut bad = buf.clone();
            let i = rng.below(bad.len());
            bad[i] ^= 1 << rng.below(8);
            if let Ok(v) = decode_share(spec, dim, &bad) {
                assert_eq!(v.len(), dim);
            }
        }
    }
}

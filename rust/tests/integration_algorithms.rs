//! End-to-end algorithm tests over the full stack (runtime + coordinator):
//! every algorithm trains, the paper's equivalences hold, and the simulated
//! timing orders methods the way Section 6 reports.

use sgp::algorithms::Algorithm;
use sgp::config::TrainConfig;
use sgp::coordinator::Trainer;
use sgp::metrics::RunResult;
use sgp::model;
use sgp::net::LinkModel;
use sgp::optim::OptimKind;
use sgp::runtime::Runtime;
use sgp::topology::{HybridSchedule, Schedule, TopologyKind};

fn runtime() -> Option<Runtime> {
    let dir = model::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

fn run(rt: &Runtime, cfg: TrainConfig, algo: Algorithm) -> RunResult {
    Trainer::new(rt, cfg, algo).unwrap().run().unwrap()
}

#[test]
fn every_algorithm_trains_and_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let n = 4;
    let algos = vec![
        Algorithm::ArSgd,
        Algorithm::sgp_1peer(n),
        Algorithm::sgp_2peer(n),
        Algorithm::osgp_1peer(n, 1),
        Algorithm::osgp_biased(n, 1),
        Algorithm::dpsgd(n),
        Algorithm::adpsgd(n),
        Algorithm::hybrid_ar_then_1p(n, 5),
        Algorithm::hybrid_2p_then_1p(n, 5),
    ];
    for algo in algos {
        let name = algo.name();
        let mut cfg = TrainConfig::test_tiny("mlp_small", n);
        cfg.epochs = 3.0;
        let r = run(&rt, cfg, algo);
        let first = r.iters.first().unwrap().train_loss;
        let last = r.final_train_loss();
        assert!(
            last < first,
            "{name}: loss did not decrease ({first} → {last})"
        );
        assert!(r.final_val_metric > 0.3, "{name}: val acc {}", r.final_val_metric);
        assert!(r.sim_total_s > 0.0);
    }
}

#[test]
fn sgp_with_complete_topology_equals_allreduce_sgd() {
    // Sec. 2: with P = (1/n)·11ᵀ and identical init, SGP ≡ parallel SGD.
    let Some(rt) = runtime() else { return };
    let n = 4;
    let mk = || {
        let mut cfg = TrainConfig::test_tiny("mlp_small", n);
        cfg.optim = OptimKind::Sgd; // pure SGD keeps the equivalence exact
        cfg.epochs = 2.0;
        cfg.eval_every_epochs = 0.0;
        cfg.track_consensus = false;
        cfg
    };
    let ar = run(&rt, mk(), Algorithm::ArSgd);
    let sgp = run(
        &rt,
        mk(),
        Algorithm::Sgp {
            schedule: HybridSchedule::single(Schedule::new(TopologyKind::Complete, n)),
        },
    );
    for (a, b) in ar.iters.iter().zip(&sgp.iters) {
        assert!(
            (a.train_loss - b.train_loss).abs() < 1e-4,
            "iter {}: AR loss {} vs SGP-complete {}",
            a.iter,
            a.train_loss,
            b.train_loss
        );
    }
    assert!((ar.final_val_loss - sgp.final_val_loss).abs() < 1e-3);
}

#[test]
fn biased_osgp_worse_than_unbiased() {
    // Table 4's ablation: dropping the push-sum weight hurts validation.
    let Some(rt) = runtime() else { return };
    let n = 8;
    let mk = || {
        let mut cfg = TrainConfig::test_tiny("mlp_small", n);
        cfg.epochs = 6.0;
        cfg.steps_per_epoch = 8;
        cfg.eval_every_epochs = 0.0;
        cfg.track_consensus = false;
        cfg
    };
    let unbiased = run(&rt, mk(), Algorithm::osgp_1peer(n, 1));
    let biased = run(&rt, mk(), Algorithm::osgp_biased(n, 1));
    assert!(
        biased.final_val_loss > unbiased.final_val_loss,
        "biased {} should exceed unbiased {}",
        biased.final_val_loss,
        unbiased.final_val_loss
    );
}

#[test]
fn simulated_timing_orders_methods_like_the_paper() {
    // On 10 GbE at ResNet-50 message sizes: OSGP < SGP < D-PSGD < AR-SGD.
    // (Timing uses the model's real message size here — a small model — so
    // force the paper-scale message by using the compute/link directly.)
    let Some(rt) = runtime() else { return };
    let n = 8;
    let mk = || {
        let mut cfg = TrainConfig::test_tiny("mlp_small", n);
        cfg.epochs = 2.0;
        cfg.eval_every_epochs = 0.0;
        cfg.track_consensus = false;
        // Slow fabric so that even the 88 KB model message matters:
        cfg.link = LinkModel {
            alpha_s: 5e-3,
            beta_bps: 1e6,
            collective_efficiency: 0.5,
            name: "slow-test-link",
        };
        cfg
    };
    let ar = run(&rt, mk(), Algorithm::ArSgd);
    let sgp = run(&rt, mk(), Algorithm::sgp_1peer(n));
    let osgp = run(&rt, mk(), Algorithm::osgp_1peer(n, 1));
    let dpsgd = run(&rt, mk(), Algorithm::dpsgd(n));
    assert!(sgp.sim_total_s < ar.sim_total_s, "SGP {} vs AR {}", sgp.sim_total_s, ar.sim_total_s);
    assert!(osgp.sim_total_s < sgp.sim_total_s, "OSGP {} vs SGP {}", osgp.sim_total_s, sgp.sim_total_s);
    assert!(dpsgd.sim_total_s > sgp.sim_total_s, "D-PSGD {} vs SGP {}", dpsgd.sim_total_s, sgp.sim_total_s);
}

#[test]
fn consensus_tracked_and_tightens_with_dense_topology() {
    let Some(rt) = runtime() else { return };
    let n = 8;
    let mk = |kind| {
        let mut cfg = TrainConfig::test_tiny("mlp_small", n);
        cfg.epochs = 3.0;
        cfg.track_consensus = true;
        (cfg, Algorithm::Sgp {
            schedule: HybridSchedule::single(Schedule::new(kind, n)),
        })
    };
    let (cfg_s, algo_s) = mk(TopologyKind::OnePeerExp);
    let (cfg_d, algo_d) = mk(TopologyKind::Complete);
    let sparse = run(&rt, cfg_s, algo_s);
    let dense = run(&rt, cfg_d, algo_d);
    let s_cons = sparse.evals.last().unwrap().consensus_mean;
    let d_cons = dense.evals.last().unwrap().consensus_mean;
    assert!(
        d_cons < s_cons,
        "dense consensus {d_cons} should beat sparse {s_cons}"
    );
    assert!(s_cons > 0.0);
}

#[test]
fn adam_trains_the_tiny_transformer() {
    let Some(rt) = runtime() else { return };
    let n = 4;
    let mut cfg = TrainConfig::test_tiny("lm_tiny", n);
    cfg.optim = OptimKind::Adam;
    cfg.lr = sgp::optim::LrSchedule::constant(3e-3);
    cfg.epochs = 5.0;
    cfg.steps_per_epoch = 8;
    cfg.track_consensus = false;
    let r = run(&rt, cfg, Algorithm::sgp_1peer(n));
    let first = r.iters.first().unwrap().train_loss;
    let last = r.final_train_loss();
    assert!(last < first - 0.2, "LM loss {first} → {last}");
}

#[test]
fn adpsgd_total_updates_match_sync_budget() {
    let Some(rt) = runtime() else { return };
    let n = 4;
    let mut cfg = TrainConfig::test_tiny("mlp_small", n);
    cfg.epochs = 2.0;
    let total = cfg.total_iters();
    let r = run(&rt, cfg, Algorithm::adpsgd(n));
    // One IterRecord per node-update ⇒ n × total records.
    assert_eq!(r.iters.len() as u64, total * n as u64);
}

#[test]
fn run_results_write_csv_series() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig::test_tiny("mlp_small", 2);
    let r = run(&rt, cfg, Algorithm::sgp_1peer(2));
    let dir = std::env::temp_dir().join("sgp_it_csv");
    r.write_csv(&dir).unwrap();
    let iters = std::fs::read_to_string(dir.join(format!("{}_iters.csv", r.label))).unwrap();
    assert!(iters.lines().count() > 5);
    let evals = std::fs::read_to_string(dir.join(format!("{}_evals.csv", r.label))).unwrap();
    assert!(evals.contains("consensus_mean"));
}

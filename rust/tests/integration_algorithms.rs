//! End-to-end algorithm tests over the full stack (runtime + coordinator):
//! every registered strategy trains through the single strategy-agnostic
//! loop, the paper's equivalences hold, and the simulated timing orders
//! methods the way Section 6 reports.
//!
//! These need the HLO artifacts from `make artifacts` (skipped otherwise);
//! the artifact-free equivalence checks live in `trait_equivalences.rs`.

use sgp::algorithms;
use sgp::config::TrainConfig;
use sgp::coordinator::TrainerBuilder;
use sgp::metrics::RunResult;
use sgp::model;
use sgp::net::LinkModel;
use sgp::optim::OptimKind;
use sgp::runtime::Runtime;
use sgp::topology::TopologyKind;

fn runtime() -> Option<Runtime> {
    let dir = model::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

fn run(rt: &Runtime, cfg: TrainConfig, algo: &str) -> RunResult {
    TrainerBuilder::new(rt)
        .config(cfg)
        .algorithm(algo)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

#[test]
fn every_registered_algorithm_trains_and_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let n = 4;
    // The whole registry, hybrids and the new DaSGD included — adding an
    // algorithm automatically adds it to this test.
    for spec in algorithms::REGISTRY {
        let mut cfg = TrainConfig::test_tiny("mlp_small", n);
        cfg.epochs = 3.0;
        let r = run(&rt, cfg, spec.name);
        let first = r.iters.first().unwrap().train_loss;
        let last = r.final_train_loss();
        assert!(
            last < first,
            "{}: loss did not decrease ({first} → {last})",
            spec.name
        );
        assert!(
            r.final_val_metric > 0.3,
            "{}: val acc {}",
            spec.name,
            r.final_val_metric
        );
        assert!(r.sim_total_s > 0.0);
    }
}

#[test]
fn sgp_with_complete_topology_equals_allreduce_sgd() {
    // Sec. 2: with P = (1/n)·11ᵀ and identical init, SGP ≡ parallel SGD.
    let Some(rt) = runtime() else { return };
    let n = 4;
    let mk = || {
        let mut cfg = TrainConfig::test_tiny("mlp_small", n);
        cfg.optim = OptimKind::Sgd; // pure SGD keeps the equivalence exact
        cfg.epochs = 2.0;
        cfg.eval_every_epochs = 0.0;
        cfg.track_consensus = false;
        cfg
    };
    let ar = run(&rt, mk(), "ar-sgd");
    let sgp = TrainerBuilder::new(&rt)
        .config(mk())
        .algorithm("sgp")
        .topology(TopologyKind::Complete)
        .build()
        .unwrap()
        .run()
        .unwrap();
    for (a, b) in ar.iters.iter().zip(&sgp.iters) {
        assert!(
            (a.train_loss - b.train_loss).abs() < 1e-4,
            "iter {}: AR loss {} vs SGP-complete {}",
            a.iter,
            a.train_loss,
            b.train_loss
        );
    }
    assert!((ar.final_val_loss - sgp.final_val_loss).abs() < 1e-3);
}

#[test]
fn biased_osgp_worse_than_unbiased() {
    // Table 4's ablation: dropping the push-sum weight hurts validation.
    let Some(rt) = runtime() else { return };
    let n = 8;
    let mk = || {
        let mut cfg = TrainConfig::test_tiny("mlp_small", n);
        cfg.epochs = 6.0;
        cfg.steps_per_epoch = 8;
        cfg.eval_every_epochs = 0.0;
        cfg.track_consensus = false;
        cfg
    };
    let unbiased = run(&rt, mk(), "osgp");
    let biased = run(&rt, mk(), "osgp-biased");
    assert!(
        biased.final_val_loss > unbiased.final_val_loss,
        "biased {} should exceed unbiased {}",
        biased.final_val_loss,
        unbiased.final_val_loss
    );
}

#[test]
fn simulated_timing_orders_methods_like_the_paper() {
    // On 10 GbE at ResNet-50 message sizes: OSGP < SGP < D-PSGD < AR-SGD.
    // (Timing uses the model's real message size here — a small model — so
    // force the paper-scale regime with a slow test fabric.)
    let Some(rt) = runtime() else { return };
    let n = 8;
    let mk = || {
        let mut cfg = TrainConfig::test_tiny("mlp_small", n);
        cfg.epochs = 2.0;
        cfg.eval_every_epochs = 0.0;
        cfg.track_consensus = false;
        // Slow fabric so that even the 88 KB model message matters:
        cfg.link = LinkModel {
            alpha_s: 5e-3,
            beta_bps: 1e6,
            collective_efficiency: 0.5,
            name: "slow-test-link",
        };
        cfg
    };
    let ar = run(&rt, mk(), "ar-sgd");
    let sgp = run(&rt, mk(), "sgp");
    let osgp = run(&rt, mk(), "osgp");
    let dpsgd = run(&rt, mk(), "dpsgd");
    assert!(sgp.sim_total_s < ar.sim_total_s, "SGP {} vs AR {}", sgp.sim_total_s, ar.sim_total_s);
    assert!(osgp.sim_total_s < sgp.sim_total_s, "OSGP {} vs SGP {}", osgp.sim_total_s, sgp.sim_total_s);
    assert!(dpsgd.sim_total_s > sgp.sim_total_s, "D-PSGD {} vs SGP {}", dpsgd.sim_total_s, sgp.sim_total_s);
}

#[test]
fn consensus_tracked_and_tightens_with_dense_topology() {
    let Some(rt) = runtime() else { return };
    let n = 8;
    let mk = |kind| {
        let mut cfg = TrainConfig::test_tiny("mlp_small", n);
        cfg.epochs = 3.0;
        cfg.track_consensus = true;
        TrainerBuilder::new(&rt)
            .config(cfg)
            .algorithm("sgp")
            .topology(kind)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let sparse = mk(TopologyKind::OnePeerExp);
    let dense = mk(TopologyKind::Complete);
    let s_cons = sparse.evals.last().unwrap().consensus_mean;
    let d_cons = dense.evals.last().unwrap().consensus_mean;
    assert!(
        d_cons < s_cons,
        "dense consensus {d_cons} should beat sparse {s_cons}"
    );
    assert!(s_cons > 0.0);
}

#[test]
fn adam_trains_the_tiny_transformer() {
    let Some(rt) = runtime() else { return };
    let n = 4;
    let mut cfg = TrainConfig::test_tiny("lm_tiny", n);
    cfg.optim = OptimKind::Adam;
    cfg.lr = sgp::optim::LrSchedule::constant(3e-3);
    cfg.epochs = 5.0;
    cfg.steps_per_epoch = 8;
    cfg.track_consensus = false;
    let r = run(&rt, cfg, "sgp");
    let first = r.iters.first().unwrap().train_loss;
    let last = r.final_train_loss();
    assert!(last < first - 0.2, "LM loss {first} → {last}");
}

#[test]
fn adpsgd_runs_one_update_per_node_per_round() {
    let Some(rt) = runtime() else { return };
    let n = 4;
    let mut cfg = TrainConfig::test_tiny("mlp_small", n);
    cfg.epochs = 2.0;
    let total = cfg.total_iters();
    let r = run(&rt, cfg, "adpsgd");
    // The unified loop records one IterRecord per round; each round is one
    // stale update per node (same gradient budget as the sync methods).
    assert_eq!(r.iters.len() as u64, total);
    assert_eq!(r.label, format!("AD-PSGD_n{n}"));
}

#[test]
fn dasgd_trains_end_to_end_through_registry() {
    // The extensibility proof: the delayed-averaging algorithm exists only
    // as algorithms/dasgd.rs + a registry row, yet the full pipeline
    // (builder → trainer loop → timing → eval) runs it like any other.
    let Some(rt) = runtime() else { return };
    let n = 8;
    let mut cfg = TrainConfig::test_tiny("mlp_small", n);
    cfg.epochs = 6.0;
    cfg.steps_per_epoch = 8;
    let mut trainer = TrainerBuilder::new(&rt)
        .config(cfg)
        .algorithm("dasgd")
        .tau(1)
        .grad_delay(2)
        .build()
        .unwrap();
    assert_eq!(trainer.algo.name(), "2-DaSGD");
    let r = trainer.run().unwrap();
    let first = r.iters.first().unwrap().train_loss;
    let last = r.final_train_loss();
    assert!(last < first, "DaSGD loss did not decrease ({first} → {last})");
    assert!(r.final_val_metric > 0.3, "val acc {}", r.final_val_metric);
    // Overlapped timing: DaSGD must not be slower than blocking SGP.
    let mut cfg2 = TrainConfig::test_tiny("mlp_small", n);
    cfg2.epochs = 6.0;
    cfg2.steps_per_epoch = 8;
    let sgp = run(&rt, cfg2, "sgp");
    assert!(r.sim_total_s <= sgp.sim_total_s * 1.01);
}

#[test]
fn custom_strategy_objects_plug_into_the_builder() {
    // The escape hatch: hand the builder a pre-built strategy object.
    let Some(rt) = runtime() else { return };
    let n = 4;
    let cfg = TrainConfig::test_tiny("mlp_small", n);
    let init = model::read_init(&model::artifacts_dir(), &rt.manifest, "mlp_small")
        .unwrap();
    let params = sgp::AlgoParams::new(n, init, cfg.optim);
    let custom = Box::new(sgp::algorithms::Sgp::with_topology(
        TopologyKind::Ring,
        &params,
    ));
    let r = TrainerBuilder::new(&rt)
        .config(cfg)
        .strategy(custom)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(r.final_train_loss() < r.iters.first().unwrap().train_loss);
}

#[test]
fn run_results_write_csv_series() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig::test_tiny("mlp_small", 2);
    let r = run(&rt, cfg, "sgp");
    let dir = std::env::temp_dir().join("sgp_it_csv");
    r.write_csv(&dir).unwrap();
    let iters = std::fs::read_to_string(dir.join(format!("{}_iters.csv", r.label))).unwrap();
    assert!(iters.lines().count() > 5);
    let evals = std::fs::read_to_string(dir.join(format!("{}_evals.csv", r.label))).unwrap();
    assert!(evals.contains("consensus_mean"));
}

//! End-to-end loopback deployment tests: spawn a real `repro coord`
//! process plus `repro worker` processes on 127.0.0.1, exchange
//! compressed push-sum shares over actual TCP sockets, and audit the
//! coordinator's summary:
//!
//! * survivors reach consensus (relative spread ≤ 1e-3, driven by the
//!   dense cool-down tail),
//! * the push-sum mass ledger balances per worker (`w = 1 + recv − sent`
//!   to f64 round-off) and globally (missing mass ≈ 0, or ≈ the killed
//!   worker's held mass),
//! * killing a worker mid-run produces the coordinator's `leave`
//!   membership event, survivor schedule re-indexing, and a final error
//!   that agrees with the in-process simulator at the same seed.
//!
//! The two-worker test is the CI `deploy-smoke` target (filtered by the
//! string `two_workers`). Both tests are bounded: every socket operation
//! in the binaries carries a timeout and the coordinator enforces an
//! overall deadline, so a regression fails loudly instead of hanging.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use sgp::faults::harness::{run_quadratic, FaultRunConfig};
use sgp::faults::FaultPlan;
use sgp::model::json::Json;
use sgp::rng::Pcg;

const BIN: &str = env!("CARGO_BIN_EXE_repro");

/// Kill the child on drop so a failed assertion cannot leak processes.
struct Reaper(Child);

impl Drop for Reaper {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sgp_deploy_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn wait_for<F: FnMut() -> bool>(what: &str, timeout: Duration, mut ready: F) {
    let deadline = Instant::now() + timeout;
    while !ready() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn read_port(dir: &Path) -> u16 {
    let path = dir.join("port");
    wait_for("coordinator port file", Duration::from_secs(30), || path.exists());
    std::fs::read_to_string(&path).unwrap().trim().parse().unwrap()
}

fn spawn_coord(dir: &Path, world: usize, rounds: u64, cooldown: u64, seed: u64) -> Reaper {
    let child = Command::new(BIN)
        .args([
            "coord",
            "--world",
            &world.to_string(),
            "--rounds",
            &rounds.to_string(),
            "--cooldown",
            &cooldown.to_string(),
            "--dim",
            "32",
            "--seed",
            &seed.to_string(),
            "--lr",
            "0.05",
            "--compress",
            "topk:4",
            "--round-ms",
            "1",
            "--round-timeout-ms",
            "1000",
            "--slow-ms",
            "2000",
            "--dead-ms",
            "10000",
            "--deadline-s",
            "90",
        ])
        .arg("--port-file")
        .arg(dir.join("port"))
        .arg("--log")
        .arg(dir.join("membership.jsonl"))
        .arg("--summary")
        .arg(dir.join("summary.json"))
        .stdout(Stdio::null())
        .spawn()
        .expect("spawning coordinator");
    Reaper(child)
}

/// Count `join` records in the coordinator's membership log.
fn joins_logged(dir: &Path) -> usize {
    log_events(dir).iter().filter(|(kind, _)| kind == "join").count()
}

/// Spawn one worker and wait until the coordinator has logged its join —
/// ranks are assigned in join order, so serializing the joins pins the
/// spawn-index ↔ rank correspondence the kill test relies on.
fn spawn_worker_ranked(dir: &Path, port: u16, rank: usize) -> Reaper {
    let w = spawn_worker(port);
    wait_for("worker join", Duration::from_secs(30), || joins_logged(dir) > rank);
    w
}

fn spawn_worker(port: u16) -> Reaper {
    let child = Command::new(BIN)
        .args(["worker", "--coord", &format!("127.0.0.1:{port}"), "--hb-ms", "50"])
        .stdout(Stdio::null())
        .spawn()
        .expect("spawning worker");
    Reaper(child)
}

/// Wait (bounded) for the coordinator to exit successfully, then parse
/// its summary JSON.
fn finish(mut coord: Reaper, dir: &Path) -> Json {
    let deadline = Instant::now() + Duration::from_secs(100);
    let status = loop {
        if let Some(s) = coord.0.try_wait().unwrap() {
            break s;
        }
        assert!(Instant::now() < deadline, "coordinator did not exit in time");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(status.success(), "coordinator exited with {status}");
    let text = std::fs::read_to_string(dir.join("summary.json")).expect("summary written");
    Json::parse(&text).expect("summary parses")
}

fn log_events(dir: &Path) -> Vec<(String, u64)> {
    std::fs::read_to_string(dir.join("membership.jsonl"))
        .unwrap_or_default()
        .lines()
        .filter_map(|l| Json::parse(l).ok())
        .map(|j| {
            (
                j.get("kind").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                j.get("rank").and_then(|v| v.as_f64()).unwrap_or(-1.0) as u64,
            )
        })
        .collect()
}

fn f64_field(j: &Json, name: &str) -> f64 {
    j.get(name)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("summary field `{name}` missing"))
}

fn f64_vec(j: &Json, name: &str) -> Vec<f64> {
    j.get(name)
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("summary array `{name}` missing"))
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect()
}

/// Quadratic centers exactly as both the workers and the fault harness
/// draw them (`Pcg::new(seed)`, one `gaussian_vec(dim)` per rank).
fn centers(seed: u64, world: usize, dim: usize) -> Vec<Vec<f32>> {
    let mut rng = Pcg::new(seed);
    (0..world).map(|_| rng.gaussian_vec(dim)).collect()
}

fn mean_of(centers: &[Vec<f32>], ranks: &[usize]) -> Vec<f64> {
    let dim = centers[0].len();
    let mut m = vec![0.0f64; dim];
    for &r in ranks {
        for (mi, v) in m.iter_mut().zip(&centers[r]) {
            *mi += *v as f64 / ranks.len() as f64;
        }
    }
    m
}

fn dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

#[test]
fn loopback_two_workers_reach_consensus_with_balanced_ledger() {
    let dir = tmp_dir("two");
    let seed = 11;
    let coord = spawn_coord(&dir, 2, 240, 80, seed);
    let port = read_port(&dir);
    let _w0 = spawn_worker(port);
    let _w1 = spawn_worker(port);
    let summary = finish(coord, &dir);

    let survivors = summary.get("survivors").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(survivors.len(), 2, "both workers must finish");
    assert!(
        f64_field(&summary, "spread") <= 1e-3,
        "consensus spread {} > 1e-3",
        f64_field(&summary, "spread")
    );
    assert!(
        f64_field(&summary, "missing_w").abs() < 1e-6,
        "no-fault run must conserve all push-sum mass (missing {})",
        f64_field(&summary, "missing_w")
    );
    assert!(
        f64_field(&summary, "max_ledger_residual") < 1e-6,
        "per-worker ledger must balance"
    );

    // The deployed consensus sits at the optimum of the joint quadratic
    // (the mean of both centers), up to the O(lr) + weight-decay floor.
    let cs = centers(seed, 2, 32);
    let opt = mean_of(&cs, &[0, 1]);
    let mean = f64_vec(&summary, "mean");
    assert!(
        dist(&mean, &opt) < 0.05,
        "deployed consensus is {} away from the quadratic optimum",
        dist(&mean, &opt)
    );
}

#[test]
fn loopback_kill_one_of_four_workers_matches_the_simulator() {
    let dir = tmp_dir("kill");
    let seed = 7;
    let world = 4;
    let rounds = 500;
    let cooldown = 150;
    let coord = spawn_coord(&dir, world, rounds, cooldown, seed);
    let port = read_port(&dir);
    let mut workers: Vec<Reaper> =
        (0..world).map(|r| spawn_worker_ranked(&dir, port, r)).collect();

    // Kill rank 2 shortly after the run starts.
    let log = dir.join("membership.jsonl");
    wait_for("assignment broadcast", Duration::from_secs(60), || {
        std::fs::read_to_string(&log).unwrap_or_default().contains("assign")
    });
    std::thread::sleep(Duration::from_millis(250));
    workers[2].0.kill().expect("killing worker 2");

    let summary = finish(coord, &dir);
    drop(workers);

    let survivors: Vec<u64> = summary
        .get("survivors")
        .and_then(|v| v.as_arr())
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as u64)
        .collect();
    assert_eq!(survivors, vec![0, 1, 3], "rank 2 was killed; the rest must finish");
    assert!(
        log_events(&dir).iter().any(|(kind, rank)| kind == "leave" && *rank == 2),
        "the kill must surface as a `leave` membership event"
    );

    let spread = f64_field(&summary, "spread");
    assert!(spread <= 1e-3, "survivor consensus spread {spread} > 1e-3");
    let missing = f64_field(&summary, "missing_w");
    assert!(
        (0.05..3.5).contains(&missing),
        "missing mass {missing} should be the killed worker's held share"
    );
    assert!(
        f64_field(&summary, "max_ledger_residual") < 1e-6,
        "survivor ledgers must balance"
    );

    // Survivors must settle at the surviving centers' mean (push-sum
    // renormalizes after the write-off) ...
    let cs = centers(seed, world, 32);
    let mean = f64_vec(&summary, "mean");
    let surv_opt = mean_of(&cs, &[0, 1, 3]);
    assert!(
        dist(&mean, &surv_opt) < 0.1,
        "deployed consensus is {} away from the survivors' optimum",
        dist(&mean, &surv_opt)
    );

    // ... which must agree with the in-process simulator under the same
    // seed and an equivalent permanent-leave fault plan. `final_err`
    // measures distance from the *full* 4-center optimum in both cases,
    // and is dominated by the same survivor-vs-full offset.
    let sim = run_quadratic(
        "sgp",
        &FaultRunConfig {
            n: world,
            iters: rounds - cooldown,
            dim: 32,
            lr: 0.05,
            seed,
            ..Default::default()
        },
        &FaultPlan::lossless().with_crash(2, (rounds - cooldown) / 3, None),
    )
    .expect("simulator run");
    let full_opt = mean_of(&cs, &[0, 1, 2, 3]);
    let deployed_err = dist(&mean, &full_opt);
    assert!(
        (deployed_err - sim.final_err).abs() <= 0.15 * sim.final_err.max(1.0),
        "deployed final error {deployed_err} disagrees with the simulator's {}",
        sim.final_err
    );

    // `repro trace` over the membership log must identify the killed
    // rank from its heartbeat/membership transitions (a `leave` with no
    // `done`) and reconcile the dropped mass against the coordinator's
    // ledger audit to 1e-9.
    let out = Command::new(BIN)
        .arg("trace")
        .arg(&log)
        .output()
        .expect("running repro trace");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "repro trace failed ({}):\n{stdout}\n{stderr}",
        out.status
    );
    assert!(
        stdout.contains("killed ranks") && stdout.contains("[2]"),
        "trace analysis must single out the killed rank:\n{stdout}"
    );
    assert!(
        stdout.contains("ledger reconciliation: OK"),
        "trace analysis must reconcile the mass ledger:\n{stdout}"
    );
}

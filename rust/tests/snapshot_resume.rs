//! Property battery for the durable snapshot format (the proptest
//! idiom, hand-rolled on the repo's seeded `Pcg` since the offline
//! build vendors no fuzzing crate):
//!
//! * `restore(save(e))` at round r continues **bit-identical** to the
//!   uninterrupted run — across exec policies × fault plans ×
//!   compression specs, through a full bytes roundtrip;
//! * a snapshot taken under one [`ExecPolicy`] resumes under another
//!   with the same bits (the policy-equivalence contract survives the
//!   disk);
//! * elastic join after a durable restore conserves Σw and leaves the
//!   joiner converging with everyone else;
//! * the sparse event engine roundtrips its template/hot-set form;
//! * RNG cursors resume their draw sequences exactly;
//! * corrupted bytes (truncation at every length, every single-bit
//!   flip, bad magic/version/kind) are *detected* — typed errors, never
//!   panics — and kind-mismatched restores are typed errors too.

use sgp::faults::{FaultClock, FaultPlan};
use sgp::gossip::event_engine::EventEngine;
use sgp::gossip::{Compression, ExecPolicy, PushSumEngine};
use sgp::rng::Pcg;
use sgp::snapshot::{EngineKind, Restored, RngCursor, Snapshot, SnapshotError};
use sgp::topology::{Schedule, TopologyKind};

fn random_init(rng: &mut Pcg, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..dim).map(|_| (rng.f32() - 0.5) * 4.0).collect())
        .collect()
}

/// Every value-bearing bit of the engine's node state.
fn state_bits(e: &PushSumEngine) -> Vec<(Vec<u32>, u64)> {
    e.states
        .iter()
        .map(|s| (s.x.iter().map(|v| v.to_bits()).collect(), s.w.to_bits()))
        .collect()
}

#[test]
fn save_restore_resumes_bit_identically_across_policies_faults_and_compression() {
    let policies = [
        ExecPolicy::Sequential,
        ExecPolicy::Parallel { shards: 3 },
        ExecPolicy::Event,
    ];
    let schemes = [
        Compression::Identity,
        Compression::TopK { den: 8 },
        Compression::Qsgd { bits: 4 },
    ];
    for case in 0..18u64 {
        let mut rng = Pcg::with_stream(0x5eed_0001, case);
        let n = 5 + rng.below(8);
        let dim = 3 + rng.below(21);
        let delay = rng.below(3) as u64;
        let seed = 0x900d + case;
        let exec = policies[(case % 3) as usize];
        let compress = schemes[((case / 3) % 3) as usize];
        // Odd cases run a churny plan whose crash window straddles the
        // save point, so restores cross a membership-epoch boundary.
        let plan = if case % 2 == 1 {
            FaultPlan::lossless()
                .with_drop(0.05)
                .with_rescue(true)
                .with_crash(1 % n, 4, Some(9))
                .with_seed(seed)
        } else {
            FaultPlan::lossless()
        };
        let clock = FaultClock::new(plan);
        let sched = Schedule::with_seed(TopologyKind::OnePeerExp, n, seed);

        let init = random_init(&mut rng, n, dim);
        let mut live = PushSumEngine::new(init.clone(), delay, false);
        let mut subject = PushSumEngine::new(init, delay, false);
        let cut = 3 + rng.below(9) as u64; // may land mid-crash
        for k in 0..cut {
            live.step_compressed(k, &sched, Some(&clock), exec, compress);
            subject.step_compressed(k, &sched, Some(&clock), exec, compress);
        }

        // Durable roundtrip: engine → bytes → decoded snapshot → engine.
        let bytes = subject.save(cut).to_bytes();
        let snap = Snapshot::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("case {case}: clean bytes must decode: {e}"));
        assert_eq!(snap.kind(), EngineKind::Dense);
        assert_eq!((snap.round(), snap.n(), snap.dim()), (cut, n, dim));
        let mut restored = PushSumEngine::restore(&snap)
            .unwrap_or_else(|e| panic!("case {case}: restore failed: {e}"));

        for k in cut..cut + 8 {
            live.step_compressed(k, &sched, Some(&clock), exec, compress);
            restored.step_compressed(k, &sched, Some(&clock), exec, compress);
        }
        assert_eq!(
            state_bits(&live),
            state_bits(&restored),
            "case {case}: n={n} dim={dim} τ={delay} {exec:?} {compress:?}"
        );
        let (_, wl) = live.total_mass_with_losses();
        let (_, wr) = restored.total_mass_with_losses();
        assert_eq!(wl.to_bits(), wr.to_bits(), "case {case}: conserved mass differs");
        assert_eq!(live.sent_count, restored.sent_count, "case {case}");
        assert_eq!(live.drop_count, restored.drop_count, "case {case}");
    }
}

#[test]
fn a_snapshot_taken_under_one_policy_resumes_identically_under_another() {
    let (n, dim, seed) = (9usize, 12usize, 0x0c0ffee_u64);
    let sched = Schedule::with_seed(TopologyKind::OnePeerExp, n, seed);
    let mut rng = Pcg::new(seed);
    let init = random_init(&mut rng, n, dim);
    let mut live = PushSumEngine::new(init.clone(), 1, false);
    let mut subject = PushSumEngine::new(init, 1, false);
    for k in 0..10 {
        live.step_exec(k, &sched, None, ExecPolicy::Sequential);
        subject.step_exec(k, &sched, None, ExecPolicy::Sequential);
    }
    let snap = Snapshot::from_bytes(&subject.save(10).to_bytes()).unwrap();
    let mut restored = PushSumEngine::restore(&snap).unwrap();
    // The live run stays sequential; the restored run switches to the
    // event policy. Bit-identity must hold anyway.
    for k in 10..20 {
        live.step_exec(k, &sched, None, ExecPolicy::Sequential);
        restored.step_exec(k, &sched, None, ExecPolicy::Event);
    }
    assert_eq!(state_bits(&live), state_bits(&restored));
}

#[test]
fn elastic_join_after_durable_restore_conserves_mass() {
    let (n0, dim, seed) = (8usize, 16usize, 0xe1a5_u64);
    let sched0 = Schedule::with_seed(TopologyKind::OnePeerExp, n0, seed);
    let sched1 = Schedule::with_seed(TopologyKind::OnePeerExp, n0 + 1, seed);
    let mut rng = Pcg::new(seed);
    let mut eng = PushSumEngine::new(random_init(&mut rng, n0, dim), 1, false);
    for k in 0..12 {
        eng.step(k, &sched0);
    }
    let snap = Snapshot::from_bytes(&eng.save(12).to_bytes()).unwrap();
    let mut eng = PushSumEngine::restore(&snap).unwrap();

    // Pre-join totals: the φ-split must reproduce Σx and Σw exactly.
    let (x_before, w_before) = eng.total_mass_with_losses();
    let joiner = eng.elastic_join(3);
    assert_eq!(joiner, n0, "join assigns the next rank");
    let (x_after, w_after) = eng.total_mass_with_losses();
    assert_eq!(w_before.to_bits(), w_after.to_bits(), "Σw must not move on join");
    for (a, b) in x_before.iter().zip(&x_after) {
        assert_eq!(a.to_bits(), b.to_bits(), "Σx must not move on join");
    }

    for k in 12..60 {
        eng.step(k, &sched1);
    }
    eng.drain();
    let (_, w_final) = eng.total_mass_with_losses();
    assert!(
        (w_final - n0 as f64).abs() <= 1e-9,
        "Σw after join + consensus tail drifted: {w_final} vs {n0}"
    );
    // The joiner holds real weight and tracks the group's estimate.
    let (mean_d, _, max_d) = eng.consensus_distance();
    assert!(eng.states[joiner].w > 0.0);
    assert!(
        max_d <= 10.0 * mean_d + 1e-6,
        "joiner (or anyone) is an outlier: mean {mean_d:e}, max {max_d:e}"
    );
}

#[test]
fn sparse_event_engine_roundtrips_and_resumes_bit_identically() {
    let (n, dim, seed) = (64usize, 5usize, 7u64);
    let sched = Schedule::with_seed(TopologyKind::OnePeerExp, n, seed);
    let mut live = EventEngine::with_template(vec![1.0; dim], n, 0, false);
    live.state_mut(3).x[0] += 2.0; // seed a hot set
    for k in 0..6 {
        live.step(k, &sched, None, Compression::Identity);
    }
    let snap = live.save(6);
    assert_eq!(snap.kind(), EngineKind::Sparse, "fast path must persist sparsely");
    let snap = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
    let mut restored = match snap.restore().unwrap() {
        Restored::Event(e) => e,
        Restored::Dense(_) => panic!("sparse snapshot restored dense"),
    };
    for k in 6..14 {
        live.step(k, &sched, None, Compression::Identity);
        restored.step(k, &sched, None, Compression::Identity);
    }
    assert_eq!(live.materialized(), restored.materialized());
    for i in 0..n {
        let (a, b) = (live.node_state(i), restored.node_state(i));
        assert_eq!(a.w.to_bits(), b.w.to_bits(), "node {i} weight");
        assert!(
            a.x.iter().zip(&b.x).all(|(p, q)| p.to_bits() == q.to_bits()),
            "node {i} numerator"
        );
    }
    let (_, wl) = live.total_mass_with_losses();
    let (_, wr) = restored.total_mass_with_losses();
    assert_eq!(wl.to_bits(), wr.to_bits());
}

#[test]
fn rng_cursors_resume_the_draw_sequence_exactly() {
    let mut harness_rng = Pcg::with_stream(0xabcd, 17);
    for _ in 0..23 {
        harness_rng.next_u64();
    }
    harness_rng.gaussian(); // arm the Box–Muller spare so it must survive too

    let mut eng = PushSumEngine::new(vec![vec![1.0f32; 3]; 4], 0, false);
    let sched = Schedule::with_seed(TopologyKind::OnePeerExp, 4, 1);
    eng.step(0, &sched);
    let mut snap = eng.save(1);
    snap.set_rngs(vec![RngCursor::of(&harness_rng)]);
    let back = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
    assert_eq!(back.rngs().len(), 1);
    let mut resumed = back.rngs()[0].to_pcg();
    for i in 0..40 {
        assert_eq!(
            harness_rng.next_u64(),
            resumed.next_u64(),
            "draw {i} diverged after the cursor roundtrip"
        );
    }
}

#[test]
fn corrupted_snapshots_error_out_cleanly_and_never_panic() {
    // A snapshot with every section populated: mail in flight (τ = 1),
    // error-feedback banks (top-k), drop ledger (faulty plan), RNG cursor.
    let mut rng = Pcg::with_stream(0xdead_0001, 0);
    let clock = FaultClock::new(
        FaultPlan::lossless().with_drop(0.2).with_rescue(false).with_seed(5),
    );
    let sched = Schedule::with_seed(TopologyKind::OnePeerExp, 6, 5);
    let mut eng = PushSumEngine::new(random_init(&mut rng, 6, 7), 1, false);
    for k in 0..8 {
        eng.step_compressed(
            k,
            &sched,
            Some(&clock),
            ExecPolicy::Sequential,
            Compression::TopK { den: 4 },
        );
    }
    let mut snap = eng.save(8);
    snap.set_rngs(vec![RngCursor::of(&rng)]);
    let bytes = snap.to_bytes();
    assert!(Snapshot::from_bytes(&bytes).is_ok(), "baseline must decode");

    // Truncation at every length: typed error, never a panic.
    for cut in 0..bytes.len() {
        assert!(
            Snapshot::from_bytes(&bytes[..cut]).is_err(),
            "truncation to {cut} of {} bytes went undetected",
            bytes.len()
        );
    }
    // Every single-bit flip: the CRC (or an earlier structural check)
    // must catch it — CRC-32 detects all single-bit errors by design.
    for (i, _) in bytes.iter().enumerate() {
        for bit in 0..8 {
            let mut bad = bytes.clone();
            bad[i] ^= 1 << bit;
            assert!(
                Snapshot::from_bytes(&bad).is_err(),
                "flipping bit {bit} of byte {i} went undetected"
            );
        }
    }
    // Header fields are rejected with their specific typed errors
    // (checked before the CRC, so a mangled header never decodes far).
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    assert!(matches!(Snapshot::from_bytes(&bad), Err(SnapshotError::BadMagic(_))));
    let mut bad = bytes.clone();
    bad[4] = 0xff; // version u16 LE at offset 4
    assert!(matches!(Snapshot::from_bytes(&bad), Err(SnapshotError::BadVersion(_))));
    let mut bad = bytes.clone();
    bad[6] = 0x7f; // engine-kind byte
    assert!(Snapshot::from_bytes(&bad).is_err());
}

#[test]
fn restoring_into_the_wrong_engine_kind_is_a_typed_error() {
    let mut eng = PushSumEngine::new(vec![vec![1.0f32; 2]; 4], 0, false);
    let sched = Schedule::with_seed(TopologyKind::OnePeerExp, 4, 1);
    eng.step(0, &sched);
    let dense = Snapshot::from_bytes(&eng.save(1).to_bytes()).unwrap();
    assert!(matches!(
        EventEngine::restore(&dense),
        Err(SnapshotError::EngineMismatch(_))
    ));

    let mut ev = EventEngine::with_template(vec![1.0; 2], 8, 0, false);
    ev.step(0, &sched_for(8), None, Compression::Identity);
    let sparse = Snapshot::from_bytes(&ev.save(1).to_bytes()).unwrap();
    assert!(matches!(
        PushSumEngine::restore(&sparse),
        Err(SnapshotError::EngineMismatch(_))
    ));
}

fn sched_for(n: usize) -> Schedule {
    Schedule::with_seed(TopologyKind::OnePeerExp, n, 1)
}

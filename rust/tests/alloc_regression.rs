//! Zero-allocation regression test for the gossip hot path: after warm-up,
//! a dense gossip round must perform **zero heap allocations** — on the
//! sequential engine, on the pooled parallel engine, and (bonus, banks
//! warmed) under top-k compression. A counting global allocator makes any
//! regression (a fresh `Vec` per message, a peer list per node, a spawned
//! thread per round, a boxed closure per dispatch…) an immediate test
//! failure instead of a silent perf cliff.
//!
//! The whole scenario lives in ONE `#[test]` so no concurrently running
//! test in this binary can allocate while a steady-state window is being
//! measured.
//!
//! Every engine below runs with an [`EngineObs`] recorder attached: the
//! observability layer is part of the hot path's zero-alloc contract
//! (ring slots are `Copy`, counters are pre-sized, timers are vDSO
//! clock reads), so it must be ON while the window is measured.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use sgp::gossip::{Compression, ExecPolicy, PushSumEngine};
use sgp::obs::EngineObs;
use sgp::runtime::pool::Pool;
use sgp::topology::{Schedule, TopologyKind};

/// `System`, with every allocation-path call counted.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Allocation-path calls observed while running `f`.
fn allocs_during(f: impl FnOnce()) -> u64 {
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    f();
    ALLOC_CALLS.load(Ordering::SeqCst) - before
}

fn init(n: usize, dim: usize) -> Vec<Vec<f32>> {
    use sgp::rng::Pcg;
    let mut rng = Pcg::new(7);
    (0..n).map(|_| rng.gaussian_vec(dim)).collect()
}

#[test]
fn dense_gossip_round_is_allocation_free_after_warmup() {
    let n = 16;
    let dim = 256;
    // Warm-up horizon: several full schedule cycles so every mailbox,
    // outbox, payload pool, peer buffer (and, for the compressed case,
    // every per-edge error-feedback bank) reaches steady capacity.
    let warm = 6 * Schedule::exp_offsets(n).len() as u64;
    let measure = 64u64;

    // --- sequential engine, identity compression, τ ∈ {0, 1} ------------
    for delay in [0u64, 1] {
        for kind in [TopologyKind::OnePeerExp, TopologyKind::TwoPeerExp] {
            let sched = Schedule::new(kind, n);
            let mut eng = PushSumEngine::new(init(n, dim), delay, false);
            eng.set_obs(Some(Box::new(EngineObs::new(n, 64))));
            let mut k = 0u64;
            for _ in 0..warm {
                eng.step(k, &sched);
                k += 1;
            }
            let allocs = allocs_during(|| {
                for _ in 0..measure {
                    eng.step(k, &sched);
                    k += 1;
                }
            });
            assert_eq!(
                allocs, 0,
                "sequential dense round allocated ({kind:?}, τ={delay}): \
                 {allocs} calls over {measure} rounds"
            );
        }
    }

    // --- pooled parallel engine: private pool, several thread counts ----
    for threads in [1usize, 2, 7] {
        let pool = Arc::new(Pool::new(threads));
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        let mut eng = PushSumEngine::new(init(n, dim), 1, false);
        eng.set_obs(Some(Box::new(EngineObs::new(n, 64))));
        eng.set_pool(Some(pool));
        let exec = ExecPolicy::parallel(4);
        let mut k = 0u64;
        for _ in 0..warm {
            eng.step_exec(k, &sched, None, exec);
            k += 1;
        }
        let allocs = allocs_during(|| {
            for _ in 0..measure {
                eng.step_exec(k, &sched, None, exec);
                k += 1;
            }
        });
        assert_eq!(
            allocs, 0,
            "pooled dense round allocated (threads={threads}): {allocs} \
             calls over {measure} rounds — the pool handoff or the shard \
             dispatch put an allocation back on the hot path"
        );
    }

    // --- compressed hot path: banks warmed over the full cycle ----------
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);
    let spec = Compression::TopK { den: 4 };
    let mut eng = PushSumEngine::new(init(n, dim), 0, false);
    eng.set_obs(Some(Box::new(EngineObs::new(n, 64))));
    let mut k = 0u64;
    for _ in 0..warm {
        eng.step_compressed(k, &sched, None, ExecPolicy::Sequential, spec);
        k += 1;
    }
    let allocs = allocs_during(|| {
        for _ in 0..measure {
            eng.step_compressed(k, &sched, None, ExecPolicy::Sequential, spec);
            k += 1;
        }
    });
    assert_eq!(
        allocs, 0,
        "compressed (topk) round allocated: {allocs} calls over {measure} \
         rounds — scratch or bank state is being reallocated"
    );

    // --- event-driven arrivals on the dense engine, τ ∈ {0, 1} ----------
    // The arrival queue (built on the first event round, heap capacity
    // settled during warm-up), the due/parked scratch and the
    // notification-pop → drain path must all stay allocation-free per
    // arrival once warm.
    for delay in [0u64, 1] {
        for kind in [TopologyKind::OnePeerExp, TopologyKind::TwoPeerExp] {
            let sched = Schedule::new(kind, n);
            let mut eng = PushSumEngine::new(init(n, dim), delay, false);
            eng.set_obs(Some(Box::new(EngineObs::new(n, 64))));
            let mut k = 0u64;
            for _ in 0..warm {
                eng.step_exec(k, &sched, None, ExecPolicy::Event);
                k += 1;
            }
            let allocs = allocs_during(|| {
                for _ in 0..measure {
                    eng.step_exec(k, &sched, None, ExecPolicy::Event);
                    k += 1;
                }
            });
            assert_eq!(
                allocs, 0,
                "event-mode round allocated ({kind:?}, τ={delay}): {allocs} \
                 calls over {measure} rounds — the arrival scheduler put an \
                 allocation back on the per-arrival path"
            );
        }
    }

    // --- sparse EventEngine, hot set saturated ---------------------------
    // Every node perturbed → every node hot: the worst steady state the
    // sparse tick has (all sends physical, all shares through the queue).
    // After the first few ticks the share-buffer pool and the arrival
    // heap reach capacity and a tick must allocate nothing.
    {
        use sgp::gossip::EventEngine;
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        let mut eng = EventEngine::with_template(vec![0.25f32; dim], n, 0, false);
        eng.set_obs(Some(Box::new(EngineObs::new(n, 64))));
        for i in 0..n {
            eng.state_mut(i).x[0] = 1.0 + i as f32;
        }
        let mut k = 0u64;
        for _ in 0..warm {
            eng.step(k, &sched, None, Compression::Identity);
            k += 1;
        }
        assert!(eng.is_sparse(), "saturation must not force the dense fall-off");
        assert_eq!(eng.materialized(), n);
        let allocs = allocs_during(|| {
            for _ in 0..measure {
                eng.step(k, &sched, None, Compression::Identity);
                k += 1;
            }
        });
        assert_eq!(
            allocs, 0,
            "sparse event tick allocated with a saturated hot set: {allocs} \
             calls over {measure} ticks — the share pool or arrival queue is \
             being reallocated"
        );
    }
}

//! Integration tests over the PJRT runtime: Rust ⇄ compiled-HLO agreement.
//! Require `make artifacts` to have run (skipped otherwise).

use sgp::data::Batch;
use sgp::gossip::PushSumEngine;
use sgp::model;
use sgp::optim::Optimizer;
use sgp::rng::Pcg;
use sgp::runtime::Runtime;
use sgp::topology::{Schedule, TopologyKind};

fn runtime() -> Option<Runtime> {
    let dir = model::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

fn mlp_batch(seed: u64, rt: &Runtime) -> Batch {
    let m = &rt.manifest;
    let b = m.model_cfg_usize("mlp_small", "batch").unwrap();
    let in_dim = m.model_cfg_usize("mlp_small", "in_dim").unwrap();
    let classes = m.model_cfg_usize("mlp_small", "classes").unwrap();
    let mut rng = Pcg::new(seed);
    Batch::Classif {
        x: rng.gaussian_vec(b * in_dim),
        y: (0..b).map(|_| rng.below(classes) as i32).collect(),
        b,
        in_dim,
    }
}

#[test]
fn train_step_returns_finite_loss_and_full_gradient() {
    let Some(rt) = runtime() else { return };
    let init = model::read_init(&rt.dir, &rt.manifest, "mlp_small").unwrap();
    let (loss, grads) = rt.train_step("mlp_small", &init, &mlp_batch(1, &rt)).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    assert_eq!(grads.len(), init.len());
    assert!(grads.iter().all(|g| g.is_finite()));
    let nonzero = grads.iter().filter(|g| **g != 0.0).count();
    assert!(nonzero > grads.len() / 2, "{nonzero} nonzero of {}", grads.len());
}

#[test]
fn different_batches_give_different_gradients() {
    let Some(rt) = runtime() else { return };
    let init = model::read_init(&rt.dir, &rt.manifest, "mlp_small").unwrap();
    let (_, g1) = rt.train_step("mlp_small", &init, &mlp_batch(1, &rt)).unwrap();
    let (_, g2) = rt.train_step("mlp_small", &init, &mlp_batch(2, &rt)).unwrap();
    assert!(g1.iter().zip(&g2).any(|(a, b)| (a - b).abs() > 1e-8));
}

#[test]
fn train_step_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let init = model::read_init(&rt.dir, &rt.manifest, "mlp_small").unwrap();
    let b = mlp_batch(3, &rt);
    let (l1, g1) = rt.train_step("mlp_small", &init, &b).unwrap();
    let (l2, g2) = rt.train_step("mlp_small", &init, &b).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(g1, g2);
}

#[test]
fn eval_step_metric_is_probability() {
    let Some(rt) = runtime() else { return };
    let init = model::read_init(&rt.dir, &rt.manifest, "mlp_small").unwrap();
    let (loss, acc) = rt.eval_step("mlp_small", &init, &mlp_batch(4, &rt)).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=1.0).contains(&acc), "acc={acc}");
}

#[test]
fn gradient_descent_through_runtime_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let mut params = model::read_init(&rt.dir, &rt.manifest, "mlp_small").unwrap();
    let b = mlp_batch(5, &rt);
    let (l0, _) = rt.train_step("mlp_small", &params, &b).unwrap();
    for _ in 0..20 {
        let (_, g) = rt.train_step("mlp_small", &params, &b).unwrap();
        for (p, gi) in params.iter_mut().zip(&g) {
            *p -= 0.1 * gi;
        }
    }
    let (l1, _) = rt.train_step("mlp_small", &params, &b).unwrap();
    assert!(l1 < l0 * 0.5, "loss {l0} → {l1}");
}

#[test]
fn rust_nesterov_matches_pallas_fused_update() {
    // The pure-Rust hot path and the L1 fused-update artifact must agree —
    // this pins the optimizer semantics across the language boundary.
    let Some(rt) = runtime() else { return };
    let p = rt.manifest.artifact("update_sgdm_mlp_small").unwrap().param_count.unwrap();
    let mut rng = Pcg::new(11);
    let x0 = rng.gaussian_vec(p);
    let g = rng.gaussian_vec(p);
    let u0 = rng.gaussian_vec(p);
    let lr = 0.07f32;

    let (x_pjrt, u_pjrt) = rt
        .update_sgdm("update_sgdm_mlp_small", &x0, &u0, &g, lr)
        .unwrap();

    let mut x_rust = x0.clone();
    let mut opt = Optimizer::Nesterov { momentum: 0.9, weight_decay: 1e-4, u: u0 };
    opt.step(&mut x_rust, &g, lr);

    for (a, b) in x_rust.iter().zip(&x_pjrt) {
        assert!((a - b).abs() < 1e-5, "x: rust={a} pjrt={b}");
    }
    if let Optimizer::Nesterov { u, .. } = &opt {
        for (a, b) in u.iter().zip(&u_pjrt) {
            assert!((a - b).abs() < 1e-5, "u: rust={a} pjrt={b}");
        }
    }
}

#[test]
fn rust_adam_matches_pallas_fused_update() {
    let Some(rt) = runtime() else { return };
    let p = rt.manifest.artifact("update_adam_mlp_small").unwrap().param_count.unwrap();
    let mut rng = Pcg::new(13);
    let x0 = rng.gaussian_vec(p);
    let g = rng.gaussian_vec(p);
    let m0 = rng.gaussian_vec(p);
    let v0: Vec<f32> = rng.gaussian_vec(p).iter().map(|v| v.abs()).collect();
    let lr = 1e-3f32;

    // Rust path: replay 1 step with preloaded state at t=4.
    let mut x_rust = x0.clone();
    let mut opt = Optimizer::Adam {
        beta1: 0.9,
        beta2: 0.98,
        eps: 1e-9,
        m: m0.clone(),
        v: v0.clone(),
        t: 3, // step() will bump to 4
    };
    opt.step(&mut x_rust, &g, lr);

    let (x_pjrt, m_pjrt, v_pjrt) = rt
        .update_adam("update_adam_mlp_small", &x0, &m0, &v0, &g, lr, 4)
        .unwrap();
    for (a, b) in x_rust.iter().zip(&x_pjrt) {
        assert!((a - b).abs() < 1e-5, "x: rust={a} pjrt={b}");
    }
    if let Optimizer::Adam { m, v, .. } = &opt {
        for (a, b) in m.iter().zip(&m_pjrt) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in v.iter().zip(&v_pjrt) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}

#[test]
fn pallas_dense_gossip_matches_rust_engine() {
    // One dense round through the MXU-tiled Pallas artifact must equal the
    // Rust PushSum engine on the complete graph.
    let Some(rt) = runtime() else { return };
    let n = 16;
    let meta = rt.manifest.artifact("gossip_dense_n16").unwrap();
    let d = meta.d.unwrap();
    let mut rng = Pcg::new(17);
    let x: Vec<f32> = rng.gaussian_vec(n * d);
    let w = vec![1.0f32; n];

    let sched = Schedule::new(TopologyKind::Complete, n);
    let p = sched.mixing_matrix(0);
    let pf: Vec<f32> = (0..n * n).map(|i| p.at(i / n, i % n) as f32).collect();
    let (x_pjrt, w_pjrt, z_pjrt) = rt.gossip_dense(n, &pf, &x, &w).unwrap();

    let init: Vec<Vec<f32>> = (0..n).map(|i| x[i * d..(i + 1) * d].to_vec()).collect();
    let mut eng = PushSumEngine::new(init, 0, false);
    eng.step(0, &sched);

    for i in 0..n {
        assert!((eng.states[i].w - w_pjrt[i] as f64).abs() < 1e-5);
        let z = eng.states[i].debiased();
        for j in 0..d {
            let a = eng.states[i].x[j];
            let b = x_pjrt[i * d + j];
            assert!((a - b).abs() < 1e-3, "x[{i},{j}]: rust={a} pjrt={b}");
            let zz = z_pjrt[i * d + j];
            assert!((z[j] - zz).abs() < 1e-3, "z[{i},{j}]: rust={} pjrt={zz}", z[j]);
        }
    }
}

#[test]
fn lm_train_step_runs_and_learns() {
    let Some(rt) = runtime() else { return };
    let mut params = model::read_init(&rt.dir, &rt.manifest, "lm_tiny").unwrap();
    let b = rt.manifest.model_cfg_usize("lm_tiny", "batch").unwrap();
    let seq = rt.manifest.model_cfg_usize("lm_tiny", "seq_len").unwrap();
    let vocab = rt.manifest.model_cfg_usize("lm_tiny", "vocab").unwrap();
    let mut rng = Pcg::new(19);
    let batch = Batch::Tokens {
        t: (0..b * (seq + 1)).map(|_| rng.below(vocab) as i32).collect(),
        b,
        seq,
    };
    let (l0, _) = rt.train_step("lm_tiny", &params, &batch).unwrap();
    // Near-uniform init ⇒ loss ≈ ln(vocab) (+ ~σ²/2 from the out-proj
    // logit variance).
    assert!(l0 > (vocab as f32).ln() - 0.5 && l0 < (vocab as f32).ln() + 1.0, "l0={l0}");
    for _ in 0..10 {
        let (_, g) = rt.train_step("lm_tiny", &params, &batch).unwrap();
        for (p, gi) in params.iter_mut().zip(&g) {
            *p -= 0.5 * gi;
        }
    }
    let (l1, _) = rt.train_step("lm_tiny", &params, &batch).unwrap();
    assert!(l1 < l0, "loss {l0} → {l1}");
}

#[test]
fn message_bytes_matches_param_count() {
    let Some(rt) = runtime() else { return };
    let p = rt.manifest.model("mlp_small").unwrap().param_count;
    assert_eq!(rt.message_bytes("mlp_small").unwrap(), p * 4 + 8);
}

#[test]
fn executable_cache_compiles_once() {
    let Some(rt) = runtime() else { return };
    let e1 = rt.executable("train_mlp_small").unwrap();
    let e2 = rt.executable("train_mlp_small").unwrap();
    assert!(std::rc::Rc::ptr_eq(&e1, &e2));
}

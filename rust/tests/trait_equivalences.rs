//! The algorithm equivalences promised in `algorithms/mod.rs`, expressed
//! through the `DistributedAlgorithm` trait with synthetic least-squares
//! gradients — no HLO artifacts needed, so these always run in tier-1.
//!
//! * SGP ≡ AR-SGD under complete `(1/n)·11ᵀ` mixing from equal starts.
//! * SGP ≡ D-PSGD under a static symmetric doubly-stochastic schedule
//!   (push-sum weights stay ≡ 1).
//! * Every registry entry survives the generic driver protocol — the
//!   contract a future algorithm is held to from the day it is added.

use sgp::algorithms::{self, AlgoParams, DaSgd, DistributedAlgorithm, RoundCtx};
use sgp::net::LinkModel;
use sgp::optim::OptimKind;
use sgp::rng::Pcg;
use sgp::topology::TopologyKind;

const DIM: usize = 16;

/// Drive one strategy through the coordinator's round protocol with
/// gradients of the node-local quadratic `f_i(z) = ½‖z − c_i‖²`.
fn drive(
    alg: &mut dyn DistributedAlgorithm,
    centers: &[Vec<f32>],
    rounds: u64,
    lr: f32,
) {
    let n = alg.n();
    let link = LinkModel::ethernet_10g();
    let comp = vec![0.1f64; n];
    let mut view = vec![0.0f32; alg.dim()];
    for k in 0..rounds {
        for i in 0..n {
            alg.local_view(i, &mut view);
            let g: Vec<f32> =
                view.iter().zip(&centers[i]).map(|(z, c)| z - c).collect();
            alg.apply_step(i, &g, lr);
        }
        let ctx = RoundCtx::new(k, &comp, 4 * DIM, &link);
        alg.communicate(&ctx);
    }
}

fn centers(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg::new(seed);
    (0..n).map(|_| rng.gaussian_vec(DIM)).collect()
}

fn params(n: usize, optim: OptimKind) -> AlgoParams {
    AlgoParams::new(n, vec![0.0f32; DIM], optim)
}

#[test]
fn sgp_under_complete_mixing_equals_arsgd() {
    let n = 8;
    let cs = centers(n, 11);
    // Pure SGD keeps the linear-algebra identity exact; Nesterov also
    // satisfies it (the update is linear in (u, g, x)) but SGD is the
    // cleanest witness.
    let mut ar = algorithms::build("ar-sgd", &params(n, OptimKind::Sgd)).unwrap();
    let mut p = params(n, OptimKind::Sgd);
    p.topology = Some(TopologyKind::Complete);
    let mut sgp = algorithms::build("sgp", &p).unwrap();

    let link = LinkModel::ethernet_10g();
    let comp = vec![0.1f64; n];
    let mut view = vec![0.0f32; DIM];
    for k in 0..40 {
        for alg in [ar.as_mut(), sgp.as_mut()] {
            for i in 0..n {
                alg.local_view(i, &mut view);
                let g: Vec<f32> =
                    view.iter().zip(&cs[i]).map(|(z, c)| z - c).collect();
                alg.apply_step(i, &g, 0.05);
            }
            let ctx = RoundCtx::new(k, &comp, 4 * DIM, &link);
            alg.communicate(&ctx);
        }
        // After each round every SGP node's de-biased view must equal the
        // replicated AR-SGD state.
        let a = ar.node_view(0);
        for i in 0..n {
            let z = sgp.node_view(i);
            for (x, y) in a.iter().zip(&z) {
                assert!(
                    (x - y).abs() < 1e-4,
                    "round {k}, node {i}: AR {x} vs SGP-complete {y}"
                );
            }
        }
    }
    assert_eq!(ar.consensus_stats(), (0.0, 0.0, 0.0));
}

#[test]
fn sgp_under_symmetric_schedule_equals_dpsgd() {
    // D-PSGD is PushSum over a doubly-stochastic symmetric schedule; run
    // SGP over that same schedule and the trajectories must coincide
    // bit-for-bit (only the *timing pattern* differs).
    let n = 16;
    let cs = centers(n, 13);
    let mut p = params(n, OptimKind::Nesterov);
    p.topology = Some(TopologyKind::BipartiteExp);
    let mut sgp = algorithms::build("sgp", &p).unwrap();
    let mut dpsgd = algorithms::build("dpsgd", &p).unwrap();

    drive(sgp.as_mut(), &cs, 60, 0.05);
    drive(dpsgd.as_mut(), &cs, 60, 0.05);

    for i in 0..n {
        let a = sgp.node_view(i);
        let b = dpsgd.node_view(i);
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (x - y).abs() < 1e-6,
                "node {i}: SGP-symmetric {x} vs D-PSGD {y}"
            );
        }
    }
    // Both consensus trajectories are identical too.
    let (sa, _, _) = sgp.consensus_stats();
    let (da, _, _) = dpsgd.consensus_stats();
    assert!((sa - da).abs() < 1e-9, "{sa} vs {da}");
}

#[test]
fn every_registry_entry_optimizes_the_quadratic() {
    // The generic contract: each strategy, driven only through the trait,
    // must move the network average toward the global optimum (mean c_i).
    let n = 8;
    let cs = centers(n, 17);
    let mut opt = vec![0.0f64; DIM];
    for c in &cs {
        for (o, v) in opt.iter_mut().zip(c) {
            *o += *v as f64 / n as f64;
        }
    }
    for spec in algorithms::REGISTRY {
        let mut p = params(n, OptimKind::Sgd);
        // Exercise the hybrids' real two-phase path (first phase for the
        // opening third of the run), not the switch_at=0 degenerate form.
        p.switch_at = 130;
        let mut alg = (spec.build)(&p).unwrap();
        drive(alg.as_mut(), &cs, 400, 0.05);
        alg.drain();
        let avg = alg.average();
        let err: f64 = avg
            .iter()
            .zip(&opt)
            .map(|(a, o)| {
                let e = *a as f64 - o;
                e * e
            })
            .sum::<f64>()
            .sqrt();
        // The biased-OSGP ablation converges to a *biased* fixed point by
        // design (Table 4) — hold it to a looser neighbourhood.
        let tol = if spec.name == "osgp-biased" { 0.6 } else { 0.2 };
        assert!(err < tol, "{}: ‖x̄ − x*‖ = {err}", spec.name);
    }
}

#[test]
fn dasgd_matches_osgp_when_gradient_delay_is_degenerate() {
    // With grad_delay = 0 the DaSGD FIFO applies immediately, so DaSGD over
    // the 1-peer graph with τ-delayed messages is exactly unbiased OSGP.
    let n = 8;
    let cs = centers(n, 19);
    let p = params(n, OptimKind::Sgd);
    let mut dasgd = DaSgd::new(TopologyKind::OnePeerExp, 1, 0, &p);
    let mut osgp = algorithms::build("osgp", &p).unwrap(); // τ clamps to 1
    drive(&mut dasgd, &cs, 50, 0.05);
    drive(osgp.as_mut(), &cs, 50, 0.05);
    for i in 0..n {
        let a = dasgd.node_view(i);
        let b = osgp.node_view(i);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "node {i}: DaSGD {x} vs OSGP {y}");
        }
    }
}

#[test]
fn dasgd_delayed_gradients_converge_with_bounded_lag() {
    let n = 8;
    let cs = centers(n, 23);
    let mut p = params(n, OptimKind::Sgd);
    p.tau = 1;
    p.grad_delay = 2;
    let mut alg = algorithms::build("dasgd", &p).unwrap();
    assert_eq!(alg.name(), "2-DaSGD");
    drive(alg.as_mut(), &cs, 600, 0.05);
    alg.drain();
    let mut opt = vec![0.0f64; DIM];
    for c in &cs {
        for (o, v) in opt.iter_mut().zip(c) {
            *o += *v as f64 / n as f64;
        }
    }
    let avg = alg.average();
    let err: f64 = avg
        .iter()
        .zip(&opt)
        .map(|(a, o)| {
            let e = *a as f64 - o;
            e * e
        })
        .sum::<f64>()
        .sqrt();
    assert!(err < 0.2, "‖x̄ − x*‖ = {err}");
    let (cons, _, _) = alg.consensus_stats();
    assert!(cons < 0.3, "consensus error {cons}");
}

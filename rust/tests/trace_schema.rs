//! Property tests for the JSONL trace schema (`sgp::obs::trace`):
//! every event the writer emits parses back bit-exactly (seeded
//! generative sweep in the repo's proptest idiom — generate → check →
//! report the counterexample seed), id-range validation rejects
//! out-of-range ranks/rounds, and the real recorders (engine + timing
//! simulator) produce traces the `repro trace` analyzer accepts.

use std::path::PathBuf;

use sgp::faults::harness::{run_quadratic, FaultRunConfig};
use sgp::faults::FaultPlan;
use sgp::gossip::{Compression, ExecPolicy, PushSumEngine};
use sgp::obs::trace::{TraceFile, TraceWriter, GLOBAL_RANK};
use sgp::obs::{analyze, EngineObs};
use sgp::rng::Pcg;
use sgp::topology::{Schedule, TopologyKind};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sgp_trace_prop_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Draw one extras value covering the writer's three encodings: the
/// integer fast path, exponent form, and `null` for non-finite.
fn arb_value(rng: &mut Pcg) -> f64 {
    match rng.below(8) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => -0.0,
        3 => (rng.below(2_000_001) as f64) - 1_000_000.0, // integer path
        4 => 9.0e15,                                      // integer-path boundary
        5 => rng.gaussian() * 1e18,                       // exponent form, huge
        6 => rng.gaussian() * 1e-18,                      // exponent form, tiny
        _ => rng.gaussian(),
    }
}

#[test]
fn every_emitted_event_parses_back_bit_exactly() {
    let dir = tmp_dir("roundtrip");
    let keys = ["w", "recv_w", "bytes", "count", "makespan_s"];
    for case in 0..50u64 {
        let mut rng = Pcg::new(31_000 + case);
        let world = 1 + rng.below(64);
        let rounds = rng.below(1000) as u64;
        let n_events = rng.below(40);
        let path = dir.join(format!("case_{case}.jsonl"));
        let mut w = TraceWriter::create(&path, "engine", world, rounds).unwrap();

        let mut expect: Vec<(u64, u32, u64, Vec<(usize, f64)>)> = Vec::new();
        for _ in 0..n_events {
            let rank =
                if rng.below(4) == 0 { GLOBAL_RANK } else { rng.below(world) as u32 };
            let round = if rounds == 0 { 0 } else { rng.below(rounds as usize + 1) as u64 };
            let t_ms = rng.below(1 << 20) as u64;
            let extras: Vec<(usize, f64)> =
                (0..rng.below(4)).map(|i| (i, arb_value(&mut rng))).collect();
            let named: Vec<(&str, f64)> =
                extras.iter().map(|(i, v)| (keys[*i], *v)).collect();
            w.event(t_ms, "round", rank, round, &named);
            expect.push((t_ms, rank, round, extras));
        }
        drop(w);

        let tf = TraceFile::load(&path).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(tf.meta.world, Some(world), "case {case}");
        assert_eq!(tf.events.len(), expect.len(), "case {case}");
        for (ev, (t_ms, rank, round, extras)) in tf.events.iter().zip(&expect) {
            assert_eq!(ev.t_ms, *t_ms, "case {case}");
            let want_rank = if *rank == GLOBAL_RANK { None } else { Some(*rank) };
            assert_eq!(ev.rank, want_rank, "case {case}");
            assert_eq!(ev.round, Some(*round), "case {case}");
            for (i, orig) in extras {
                let got = ev.num(keys[*i]).unwrap_or_else(|| {
                    panic!("case {case}: extras key {} lost", keys[*i])
                });
                if orig.is_finite() {
                    assert_eq!(
                        got.to_bits(),
                        orig.to_bits(),
                        "case {case}: {} = {orig:?} came back as {got:?}",
                        keys[*i]
                    );
                } else {
                    // Non-finite values are written as JSON null and read
                    // back as NaN (the repo parser rejects bare NaN/inf).
                    assert!(got.is_nan(), "case {case}: non-finite must read as NaN");
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn parser_enforces_rank_and_round_ranges() {
    for case in 0..30u64 {
        let mut rng = Pcg::new(32_000 + case);
        let world = 1 + rng.below(16);
        let rounds = rng.below(500) as u64;
        let meta = format!(
            "{{\"schema\":\"sgp-trace\",\"v\":1,\"source\":\"x\",\
             \"world\":{world},\"rounds\":{rounds}}}"
        );
        let bad_rank = world + rng.below(10);
        let text = format!(
            "{meta}\n{{\"t_ms\":0,\"kind\":\"e\",\"rank\":{bad_rank},\"round\":0}}\n"
        );
        let err = TraceFile::parse(&text).expect_err("rank ≥ world must be rejected");
        assert!(err.to_string().contains("rank"), "case {case}: {err}");

        let bad_round = rounds + 1 + rng.below(10) as u64;
        let text = format!(
            "{meta}\n{{\"t_ms\":0,\"kind\":\"e\",\"rank\":0,\"round\":{bad_round}}}\n"
        );
        let err = TraceFile::parse(&text).expect_err("round > rounds must be rejected");
        assert!(err.to_string().contains("round"), "case {case}: {err}");

        // In-range boundary values must pass.
        let text = format!(
            "{meta}\n{{\"t_ms\":0,\"kind\":\"e\",\"rank\":{},\"round\":{rounds}}}\n",
            world - 1
        );
        TraceFile::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

#[test]
fn engine_recorder_trace_loads_and_analyzes() {
    let dir = tmp_dir("engine");
    let n = 8;
    let iters = 30u64;
    let mut rng = Pcg::new(9);
    let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(16)).collect();
    let mut eng = PushSumEngine::new(init, 0, false);
    eng.set_obs(Some(Box::new(EngineObs::new(n, 16))));
    let sched = Schedule::new(TopologyKind::OnePeerExp, n);
    let spec = Compression::TopK { den: 4 };
    for k in 0..iters {
        eng.step_compressed(k, &sched, None, ExecPolicy::Sequential, spec);
    }
    let obs = eng.take_obs().expect("recorder must come back out");
    let (rounds, msgs, _, _, wire_bytes) = obs.totals();
    assert_eq!(rounds, iters, "every round must be recorded");
    assert_eq!(msgs, iters * n as u64, "one-peer topology sends n messages per round");
    assert!(wire_bytes > 0, "compressed bytes must be charged");

    let path = dir.join("engine.jsonl");
    sgp::obs::trace::write_engine_trace(&path, &obs, iters).unwrap();
    let tf = TraceFile::load(&path).unwrap();
    assert_eq!(tf.meta.source, "engine");
    assert!(tf.events.iter().filter(|e| e.kind == "round").count() == 16, "ring cap");
    assert!(tf.events.iter().any(|e| e.kind == "edge"), "edge matrix rides along");
    analyze::run(&path).expect("analyzer accepts its own schema");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sim_harness_trace_loads_and_analyzes() {
    let dir = tmp_dir("sim");
    let path = dir.join("sim.jsonl");
    let cfg = FaultRunConfig {
        n: 8,
        iters: 40,
        trace: Some(path.clone()),
        ..Default::default()
    };
    run_quadratic("sgp", &cfg, &FaultPlan::lossless().with_drop(0.05)).unwrap();
    let tf = TraceFile::load(&path).unwrap();
    assert_eq!(tf.meta.source, "sim");
    assert_eq!(tf.meta.world, Some(8));
    assert_eq!(
        tf.events.iter().filter(|e| e.kind == "iter").count(),
        40,
        "one iter event per simulated round"
    );
    let straggler_total: f64 = tf
        .events
        .iter()
        .filter(|e| e.kind == "straggler")
        .filter_map(|e| e.num("count"))
        .sum();
    assert_eq!(straggler_total as u64, 40, "straggler counts partition the iterations");
    analyze::run(&path).expect("analyzer accepts sim traces");
    std::fs::remove_dir_all(&dir).ok();
}

//! Regression tests for the `metrics::RunResult` CSV emitters: exact
//! headers (the plotting pipeline keys on column names), row counts, a
//! numeric round-trip through `f64::parse` within the emitters' fixed
//! precision, and NaN handling (an empty run's NaN loss must emit a
//! token `f64::parse` accepts, not poison the file).

use std::path::PathBuf;

use sgp::metrics::{EvalRecord, IterRecord, RunResult};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sgp_metrics_csv_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn eval(iter: u64, val_loss: f64, consensus: f64) -> EvalRecord {
    EvalRecord {
        iter,
        epoch: iter as f64 / 16.0,
        sim_time_s: iter as f64 * 0.25,
        val_loss,
        val_metric: 0.5,
        node_metric_min: 0.4,
        node_metric_mean: 0.5,
        node_metric_max: 0.6,
        consensus_mean: consensus,
        consensus_min: consensus * 0.5,
        consensus_max: consensus * 2.0,
    }
}

#[test]
fn csv_headers_and_row_counts_are_exact() {
    let dir = tmp_dir("headers");
    let mut r = RunResult { label: "hdr".into(), ..Default::default() };
    for i in 0..3 {
        r.iters.push(IterRecord {
            iter: i,
            epoch: i as f64 / 16.0,
            train_loss: 2.0 - i as f64 * 0.5,
            sim_time_s: i as f64 * 0.25,
            lr: 0.1,
        });
    }
    r.evals.push(eval(0, 2.0, 1e-3));
    r.evals.push(eval(2, 1.0, 1e-4));
    r.write_csv(&dir).unwrap();

    let iters = std::fs::read_to_string(dir.join("hdr_iters.csv")).unwrap();
    let mut lines = iters.lines();
    assert_eq!(lines.next(), Some("iter,epoch,train_loss,sim_time_s,lr"));
    assert_eq!(lines.count(), 3, "one row per IterRecord");

    let evals = std::fs::read_to_string(dir.join("hdr_evals.csv")).unwrap();
    let mut lines = evals.lines();
    assert_eq!(
        lines.next(),
        Some(
            "iter,epoch,sim_time_s,val_loss,val_metric,node_min,node_mean,node_max,\
             consensus_mean,consensus_min,consensus_max"
        )
    );
    assert_eq!(lines.count(), 2, "one row per EvalRecord");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn csv_rows_round_trip_within_emitter_precision() {
    let dir = tmp_dir("roundtrip");
    let mut r = RunResult { label: "rt".into(), ..Default::default() };
    r.iters.push(IterRecord {
        iter: 41,
        epoch: 2.5625,
        train_loss: 0.123456,
        sim_time_s: 10.25,
        lr: 0.0125,
    });
    r.evals.push(eval(41, 0.654321, 3.25e-5));
    r.write_csv(&dir).unwrap();

    let iters = std::fs::read_to_string(dir.join("rt_iters.csv")).unwrap();
    let row: Vec<f64> =
        iters.lines().nth(1).unwrap().split(',').map(|c| c.parse().unwrap()).collect();
    assert_eq!(row[0], 41.0);
    assert!((row[1] - 2.5625).abs() < 5e-5, "epoch at {{:.4}} precision");
    assert!((row[2] - 0.123456).abs() < 5e-7, "train_loss at {{:.6}} precision");
    assert!((row[3] - 10.25).abs() < 5e-5);
    assert!((row[4] - 0.0125).abs() < 5e-7);

    let evals = std::fs::read_to_string(dir.join("rt_evals.csv")).unwrap();
    let row: Vec<f64> =
        evals.lines().nth(1).unwrap().split(',').map(|c| c.parse().unwrap()).collect();
    assert_eq!(row.len(), 11, "evals row matches the 11-column header");
    assert!((row[3] - 0.654321).abs() < 5e-7);
    // Consensus columns use {:.6e}: relative, not absolute, precision.
    assert!((row[8] - 3.25e-5).abs() / 3.25e-5 < 1e-6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn csv_nan_cells_stay_parseable() {
    let dir = tmp_dir("nan");
    let mut r = RunResult { label: "nan".into(), ..Default::default() };
    r.iters.push(IterRecord {
        iter: 0,
        epoch: 0.0,
        train_loss: f64::NAN,
        sim_time_s: 0.0,
        lr: 0.1,
    });
    r.evals.push(eval(0, f64::NAN, f64::NAN));
    r.write_csv(&dir).unwrap();

    for file in ["nan_iters.csv", "nan_evals.csv"] {
        let text = std::fs::read_to_string(dir.join(file)).unwrap();
        let row = text.lines().nth(1).unwrap();
        assert_eq!(row.lines().count(), 1, "{file}: NaN must not break the row structure");
        for cell in row.split(',') {
            let v: f64 = cell
                .parse()
                .unwrap_or_else(|e| panic!("{file}: cell `{cell}` unparseable: {e}"));
            let _ = v; // NaN parses to NaN; finite cells parse to themselves
        }
    }
    let text = std::fs::read_to_string(dir.join("nan_iters.csv")).unwrap();
    let loss_cell = text.lines().nth(1).unwrap().split(',').nth(2).unwrap();
    assert!(loss_cell.parse::<f64>().unwrap().is_nan(), "NaN loss must read back as NaN");
    std::fs::remove_dir_all(&dir).ok();
}

//! Property-based tests over the coordinator's invariants.
//!
//! The offline build has no proptest, so properties are checked with a
//! seeded random-case generator (hundreds of cases per property,
//! deterministic seeds, failing case printed via assert message) — same
//! spirit: generate → check invariant → report the counterexample seed.

use sgp::data::{Batch, BigramLm, Blobs};
use sgp::faults::harness::{run_quadratic, FaultRunConfig};
use sgp::faults::{Degradation, FaultClock, FaultPlan};
use sgp::gossip::{Compression, ExecPolicy, PushSumEngine};
use sgp::model::json::Json;
use sgp::net::{CommPattern, ComputeModel, LinkModel, TimingSim};
use sgp::rng::Pcg;
use sgp::sim::EventQueue;
use sgp::topology::{Schedule, TopologyKind};

const KINDS: &[TopologyKind] = &[
    TopologyKind::OnePeerExp,
    TopologyKind::TwoPeerExp,
    TopologyKind::Complete,
    TopologyKind::CompleteCycling,
    TopologyKind::RandomExp,
    TopologyKind::RandomAny,
    TopologyKind::Ring,
    TopologyKind::BipartiteExp,
];

fn arb_n(rng: &mut Pcg) -> usize {
    [2, 3, 4, 5, 8, 13, 16, 32][rng.below(8)]
}

#[test]
fn prop_mixing_matrices_always_column_stochastic() {
    for case in 0..300u64 {
        let mut rng = Pcg::new(case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let k = rng.next_u64() % 1000;
        let s = Schedule::with_seed(kind, n, case);
        let p = s.mixing_matrix(k);
        assert!(
            p.is_column_stochastic(1e-12),
            "case {case}: {kind:?} n={n} k={k} not column stochastic"
        );
    }
}

#[test]
fn prop_one_peer_routing_balanced() {
    // Every node sends exactly one message and receives exactly one, at
    // every iteration, for every n (the paper's balanced-load claim).
    for case in 0..200u64 {
        let mut rng = Pcg::new(case);
        let n = arb_n(&mut rng);
        let s = Schedule::new(TopologyKind::OnePeerExp, n);
        let k = rng.next_u64() % 64;
        let mut recv = vec![0usize; n];
        for i in 0..n {
            let peers = s.out_peers(i, k);
            assert_eq!(peers.len(), 1, "case {case}: node {i} sends {peers:?}");
            assert_ne!(peers[0], i, "case {case}: self-send");
            recv[peers[0]] += 1;
        }
        assert!(
            recv.iter().all(|&r| r == 1),
            "case {case}: n={n} k={k} recv={recv:?}"
        );
    }
}

#[test]
fn prop_pushsum_mass_conserved_under_any_schedule_and_delay() {
    for case in 0..60u64 {
        let mut rng = Pcg::new(1000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let d = 1 + rng.below(16);
        let delay = rng.below(4) as u64;
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
        let mut eng = PushSumEngine::new(init, delay, false);
        let (x0, w0) = eng.total_mass();
        let s = Schedule::with_seed(kind, n, case);
        for k in 0..30 {
            eng.step(k, &s);
        }
        eng.drain();
        let (x1, w1) = eng.total_mass();
        for (a, b) in x0.iter().zip(&x1) {
            assert!(
                (a - b).abs() < 1e-2,
                "case {case}: {kind:?} n={n} delay={delay}: x mass {a} → {b}"
            );
        }
        assert!((w0 - w1).abs() < 1e-9, "case {case}: w mass {w0} → {w1}");
    }
}

#[test]
fn prop_pushsum_weights_positive_and_debias_finite() {
    for case in 0..60u64 {
        let mut rng = Pcg::new(2000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let delay = rng.below(3) as u64;
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(4)).collect();
        let mut eng = PushSumEngine::new(init, delay, false);
        let s = Schedule::with_seed(kind, n, case);
        for k in 0..50 {
            eng.step(k, &s);
            for st in &eng.states {
                assert!(st.w > 0.0, "case {case}: w={} at k={k}", st.w);
                assert!(
                    st.debiased().iter().all(|v| v.is_finite()),
                    "case {case}: non-finite debias"
                );
            }
        }
    }
}

#[test]
fn prop_pushsum_converges_to_average_on_connected_schedules() {
    // Strong-connectivity kinds must drive consensus error toward zero.
    let kinds = [
        TopologyKind::OnePeerExp,
        TopologyKind::TwoPeerExp,
        TopologyKind::Complete,
        TopologyKind::CompleteCycling,
        TopologyKind::Ring,
    ];
    for case in 0..40u64 {
        let mut rng = Pcg::new(3000 + case);
        let kind = kinds[rng.below(kinds.len())];
        let n = [4usize, 8, 16][rng.below(3)];
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(8)).collect();
        let mut eng = PushSumEngine::new(init, 0, false);
        let s = Schedule::with_seed(kind, n, case);
        let before = eng.consensus_distance().0;
        for k in 0..120 {
            eng.step(k, &s);
        }
        let after = eng.consensus_distance().0;
        // The ring's spectral gap is O(1/n²) — it contracts far more
        // slowly than the exponential/complete families (that slowness is
        // exactly Appendix A's point), so it gets a looser bound.
        let tol = if kind == TopologyKind::Ring { 0.15 } else { 1e-2 };
        assert!(
            after < before * tol + 1e-5,
            "case {case}: {kind:?} n={n}: {before} → {after}"
        );
    }
}

#[test]
fn prop_osgp_staleness_bounded_by_tau() {
    for case in 0..50u64 {
        let mut rng = Pcg::new(4000 + case);
        let n = arb_n(&mut rng);
        let tau = 1 + rng.below(3) as u64;
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(4)).collect();
        let mut eng = PushSumEngine::new(init, tau, false);
        let s = Schedule::new(TopologyKind::OnePeerExp, n);
        for k in 0..40 {
            eng.step(k, &s);
            assert!(
                eng.max_staleness(k) <= tau,
                "case {case}: staleness {} > τ={tau}",
                eng.max_staleness(k)
            );
        }
    }
}

/// Draw a random fault plan: drop rate, maybe rescue, random crashes
/// (rejoining or permanent), a random degradation window.
fn arb_plan(rng: &mut Pcg, n: usize, horizon: u64, seed: u64) -> FaultPlan {
    let mut plan = FaultPlan::lossless()
        .with_drop(rng.f64() * 0.3)
        .with_rescue(rng.f64() < 0.3)
        .with_seed(seed);
    for _ in 0..rng.below(3) {
        let node = rng.below(n);
        let at = rng.next_u64() % horizon.max(1);
        let rejoin = if rng.f64() < 0.5 {
            Some(at + 1 + rng.next_u64() % horizon.max(1))
        } else {
            None
        };
        plan = plan.with_crash(node, at, rejoin);
    }
    if rng.f64() < 0.5 {
        let from = rng.next_u64() % horizon.max(1);
        plan = plan.with_degradation(Degradation {
            from,
            until: from + 1 + rng.next_u64() % horizon.max(1),
            alpha_mult: 1.0 + rng.f64() * 9.0,
            beta_div: 1.0 + rng.f64() * 9.0,
        });
    }
    plan
}

#[test]
fn prop_fault_mode_mass_conserved_under_any_plan() {
    // The fault-mode conservation law: Σᵢ xᵢ + in-flight + recorded-dropped
    // mass is invariant under ANY fault plan — drops, rescue, churn,
    // degradations, any schedule, any delay.
    for case in 0..60u64 {
        let mut rng = Pcg::new(11_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let d = 1 + rng.below(16);
        let delay = rng.below(4) as u64;
        let plan = arb_plan(&mut rng, n, 30, case);
        let clock = FaultClock::new(plan);
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
        let mut eng = PushSumEngine::new(init, delay, false);
        let (x0, w0) = eng.total_mass_with_losses();
        let s = Schedule::with_seed(kind, n, case);
        for k in 0..30 {
            eng.step_faulty(k, &s, &clock);
            let (x, w) = eng.total_mass_with_losses();
            for (a, b) in x.iter().zip(&x0) {
                assert!(
                    (a - b).abs() < 1e-2,
                    "case {case}: {kind:?} n={n} delay={delay} k={k}: x {a} → {b}"
                );
            }
            assert!((w - w0).abs() < 1e-9, "case {case} k={k}: w {w0} → {w}");
        }
        eng.drain();
        let (x1, w1) = eng.total_mass_with_losses();
        for (a, b) in x0.iter().zip(&x1) {
            assert!((a - b).abs() < 1e-2, "case {case}: post-drain x {a} → {b}");
        }
        assert!((w0 - w1).abs() < 1e-9, "case {case}: post-drain w");
        // Weights stay positive and the de-biased views stay finite even
        // under loss and churn.
        for st in &eng.states {
            assert!(st.w > 0.0, "case {case}: w={}", st.w);
            assert!(st.debiased().iter().all(|v| v.is_finite()), "case {case}");
        }
    }
}

/// Draw a random non-identity compression spec.
fn arb_compression(rng: &mut Pcg) -> Compression {
    if rng.f64() < 0.5 {
        Compression::TopK { den: [2u32, 4, 8, 16][rng.below(4)] }
    } else {
        Compression::Qsgd { bits: [2u8, 4, 8][rng.below(3)] }
    }
}

#[test]
fn prop_compressed_mass_conserved_across_topologies_and_fault_plans() {
    // The compression half of the conservation law: with top-k or
    // quantized messages, error feedback and the φ weight-split, both Σx
    // and Σw over states + in-flight + per-edge banks + the drop ledger
    // are invariant — for random topologies, random fault plans (drops,
    // rescue, churn) and random delays.
    for case in 0..60u64 {
        let mut rng = Pcg::new(13_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let d = 1 + rng.below(24);
        let delay = rng.below(3) as u64;
        let spec = arb_compression(&mut rng);
        let faulty = rng.f64() < 0.6;
        let plan = arb_plan(&mut rng, n, 30, case);
        let clock = FaultClock::new(plan);
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
        let mut eng = PushSumEngine::new(init, delay, false);
        let (x0, w0) = eng.total_mass_with_losses();
        let s = Schedule::with_seed(kind, n, case);
        for k in 0..30 {
            let fc = faulty.then_some(&clock);
            eng.step_compressed(k, &s, fc, ExecPolicy::Sequential, spec);
            let (x, w) = eng.total_mass_with_losses();
            for (a, b) in x.iter().zip(&x0) {
                assert!(
                    (a - b).abs() < 1e-2,
                    "case {case}: {kind:?} {spec:?} n={n} k={k}: x {a} → {b}"
                );
            }
            assert!((w - w0).abs() < 1e-9, "case {case} {spec:?} k={k}: w");
        }
        // Drain re-absorbs the banks: the plain state+in-flight+ledger
        // mass is whole again and the bank is empty, x and w alike.
        eng.drain();
        let (rx, rw) = eng.residual_mass();
        assert!(rx.iter().all(|v| *v == 0.0) && rw == 0.0, "case {case}");
        let (x1, w1) = eng.total_mass_with_losses();
        for (a, b) in x1.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-2, "case {case}: post-drain x {a} → {b}");
        }
        assert!((w1 - w0).abs() < 1e-9, "case {case}: post-drain w");
        for st in &eng.states {
            assert!(st.w > 0.0 && st.debiased().iter().all(|v| v.is_finite()));
        }
    }
}

#[test]
fn prop_compressed_parallel_engine_bit_identical_to_sequential() {
    // The determinism contract extended to compression: at shard counts
    // {2, 7} a compressed run — with or without a fault plan — is
    // bit-identical to the sequential engine (states, weights, residual
    // bank, counters).
    for case in 0..30u64 {
        let mut rng = Pcg::new(14_000 + case);
        let kind = KINDS[rng.below(KINDS.len())];
        let n = arb_n(&mut rng);
        let d = 1 + rng.below(16);
        let delay = rng.below(3) as u64;
        let spec = arb_compression(&mut rng);
        let faulty = rng.f64() < 0.5;
        let plan = arb_plan(&mut rng, n, 25, case);
        let clock = FaultClock::new(plan);
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
        let s = Schedule::with_seed(kind, n, case);
        for shards in [2usize, 7] {
            let mut seq = PushSumEngine::new(init.clone(), delay, false);
            let mut par = PushSumEngine::new(init.clone(), delay, false);
            for k in 0..25 {
                let fc = faulty.then_some(&clock);
                seq.step_compressed(k, &s, fc, ExecPolicy::Sequential, spec);
                par.step_compressed(k, &s, fc, ExecPolicy::parallel(shards), spec);
            }
            let tag = format!("case {case}: {kind:?} {spec:?} n={n} shards={shards}");
            for (a, b) in seq.states.iter().zip(&par.states) {
                assert_eq!(a.x, b.x, "{tag}: numerator");
                assert_eq!(a.w.to_bits(), b.w.to_bits(), "{tag}: weight");
            }
            let ((rxa, rwa), (rxb, rwb)) =
                (seq.residual_mass(), par.residual_mass());
            for (a, b) in rxa.iter().zip(&rxb) {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: bank x");
            }
            assert_eq!(rwa.to_bits(), rwb.to_bits(), "{tag}: bank w");
            assert_eq!(seq.sent_count, par.sent_count, "{tag}: sent counter");
            assert_eq!(seq.drop_count, par.drop_count, "{tag}: drop counter");
        }
    }
}

#[test]
fn prop_fault_runs_deterministic_per_seed() {
    // Same fault seed ⇒ bit-identical metrics, across algorithms and
    // random plans; a different fault seed perturbs the history.
    // Comparisons go through to_bits so a destabilized naive-loss run
    // (inf/NaN — see DESIGN.md §Faults) still replays bit-identically.
    let bits = |s: &sgp::faults::harness::FaultRunStats| {
        (s.final_err.to_bits(), s.consensus.to_bits(), s.makespan.to_bits())
    };
    let cfg = FaultRunConfig { n: 8, iters: 40, ..FaultRunConfig::default() };
    for case in 0..6u64 {
        let mut rng = Pcg::new(12_000 + case);
        let algo = ["sgp", "osgp", "dpsgd", "ar-sgd", "adpsgd", "dasgd"]
            [rng.below(6)];
        let plan = arb_plan(&mut rng, cfg.n, cfg.iters, case).with_drop(0.1);
        let a = run_quadratic(algo, &cfg, &plan).unwrap();
        let b = run_quadratic(algo, &cfg, &plan).unwrap();
        assert_eq!(
            bits(&a),
            bits(&b),
            "case {case}: {algo} replay must be bit-identical"
        );
        let c =
            run_quadratic(algo, &cfg, &plan.clone().with_seed(999 + case)).unwrap();
        assert!(
            c.makespan.to_bits() != a.makespan.to_bits()
                || c.final_err.to_bits() != a.final_err.to_bits(),
            "case {case}: {algo} must react to the fault seed"
        );
    }
}

#[test]
fn prop_event_queue_causal_under_random_load() {
    for case in 0..100u64 {
        let mut rng = Pcg::new(5000 + case);
        let mut q: EventQueue<u32> = EventQueue::new();
        let mut pending = 0usize;
        let mut last = 0.0f64;
        for _ in 0..200 {
            if pending == 0 || rng.f64() < 0.6 {
                let t = q.now() + rng.f64() * 10.0;
                q.push(t, rng.next_u32());
                pending += 1;
            } else {
                let ev = q.pop().unwrap();
                assert!(
                    ev.time >= last,
                    "case {case}: time went backwards {last} → {}",
                    ev.time
                );
                last = ev.time;
                pending -= 1;
            }
        }
    }
}

#[test]
fn prop_timing_sim_clocks_monotone() {
    for case in 0..60u64 {
        let mut rng = Pcg::new(6000 + case);
        let n = arb_n(&mut rng);
        let link = if rng.f64() < 0.5 {
            LinkModel::ethernet_10g()
        } else {
            LinkModel::infiniband_100g()
        };
        let compute =
            ComputeModel { base_s: 0.1, jitter_sigma: 0.3, p_slow: 0.05, slow_factor: 4.0 };
        let mut sim = TimingSim::new(n, link);
        let sched = Schedule::new(TopologyKind::OnePeerExp, n);
        let mut prev_t = vec![0.0; n];
        let mut prev_makespan = 0.0;
        for k in 0..50u64 {
            let comp = compute.sample_all(n, &mut rng);
            let pattern = match k % 3 {
                0 => CommPattern::AllReduce { bytes: 1 << 20 },
                1 => CommPattern::PushSum { schedule: &sched, bytes: 1 << 20, tau: 1 },
                _ => CommPattern::Symmetric {
                    schedule: &sched,
                    bytes: 1 << 20,
                    handshake: 2.0,
                },
            };
            let makespan = sim.advance(&pattern, &comp);
            for (i, (&a, &b)) in prev_t.iter().zip(&sim.t).enumerate() {
                assert!(b >= a, "case {case}: node {i} clock {a} → {b}");
            }
            assert!(makespan >= prev_makespan, "case {case}: makespan shrank");
            prev_t = sim.t.clone();
            prev_makespan = makespan;
        }
    }
}

#[test]
fn prop_union_graph_strongly_connected_over_cycle() {
    for n in [2usize, 4, 5, 8, 11, 16, 32] {
        for kind in [TopologyKind::OnePeerExp, TopologyKind::TwoPeerExp] {
            let s = Schedule::new(kind, n);
            let b = s.cycle_len() as u64;
            assert!(
                s.union_reachable(0, b.max(1)),
                "{kind:?} n={n} union over cycle not strongly connected"
            );
        }
    }
}

#[test]
fn prop_data_batches_deterministic_and_well_shaped() {
    for case in 0..80u64 {
        let mut rng = Pcg::new(7000 + case);
        let n = arb_n(&mut rng);
        let h = rng.f64();
        let blobs = Blobs::new(
            1 + rng.below(32),
            2 + rng.below(12),
            1 + rng.below(64),
            n,
            h,
            case,
        );
        let node = rng.below(n);
        let step = rng.next_u64() % 1000;
        match (blobs.train_batch(node, step), blobs.train_batch(node, step)) {
            (
                Batch::Classif { x: x1, y: y1, b, in_dim },
                Batch::Classif { x: x2, y: y2, .. },
            ) => {
                assert_eq!(x1, x2, "case {case}");
                assert_eq!(y1, y2);
                assert_eq!(x1.len(), b * in_dim);
                assert!(x1.iter().all(|v| v.is_finite()));
            }
            _ => panic!("wrong batch type"),
        }
        let vocab = 8 + rng.below(120);
        let lm = BigramLm::new(vocab, 1 + rng.below(32), 1 + rng.below(8), n, h, case);
        match lm.train_batch(node, step) {
            Batch::Tokens { t, b, seq } => {
                assert_eq!(t.len(), b * (seq + 1), "case {case}");
                assert!(t.iter().all(|&v| v >= 0 && (v as usize) < vocab));
            }
            _ => panic!("wrong batch type"),
        }
    }
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    for case in 0..500u64 {
        let mut rng = Pcg::new(8000 + case);
        let len = rng.below(64);
        let charset = br#"{}[]",:0123456789.truefalsn\ e-"#;
        let s: String = (0..len)
            .map(|_| charset[rng.below(charset.len())] as char)
            .collect();
        let _ = Json::parse(&s); // must return Ok or Err, never panic
    }
}

#[test]
fn prop_json_roundtrips_numbers() {
    for case in 0..200u64 {
        let mut rng = Pcg::new(9000 + case);
        let v = (rng.f64() - 0.5) * 1e6;
        let s = format!("{v}");
        let parsed = Json::parse(&s).unwrap();
        assert!((parsed.as_f64().unwrap() - v).abs() < 1e-9 * v.abs().max(1.0));
    }
}

#[test]
fn prop_symmetric_schedule_keeps_pushsum_weights_at_one() {
    // D-PSGD-as-PushSum: under the bipartite symmetric schedule the mixing
    // is doubly stochastic, so w ≡ 1 forever (the SGP ⊇ D-PSGD claim).
    for n in [2usize, 4, 8, 16, 32] {
        let mut rng = Pcg::new(n as u64);
        let init: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(4)).collect();
        let mut eng = PushSumEngine::new(init, 0, false);
        let s = Schedule::new(TopologyKind::BipartiteExp, n);
        for k in 0..40 {
            eng.step(k, &s);
            for st in &eng.states {
                assert!(
                    (st.w - 1.0).abs() < 1e-9,
                    "n={n} k={k}: w={} drifted",
                    st.w
                );
            }
        }
    }
}

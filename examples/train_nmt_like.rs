//! End-to-end driver (the EXPERIMENTS.md §E2E run): train the ~1M-param
//! transformer LM for a few hundred steps across 8 simulated nodes with
//! Adam-SGP, and compare against AllReduce-Adam under the same budget —
//! the paper's WMT'16 experiment (Fig. 3) scaled to this testbed.
//!
//!     make artifacts && cargo run --release --example train_nmt_like
//!
//! Proves the full stack composes: Pallas kernels (blocked matmul + flash
//! attention) → JAX fwd/bwd → HLO text → PJRT runtime → Rust coordinator
//! (PushSum gossip + Adam + network simulation). Loss curves land in
//! `results/`.

use anyhow::Result;

use sgp::config::TrainConfig;
use sgp::coordinator::TrainerBuilder;
use sgp::experiments::results_dir;
use sgp::optim::{LrSchedule, OptimKind};
use sgp::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    let nodes = 8;
    let model = "lm_small";
    let p = rt.manifest.model(model)?.param_count;
    println!("model {model}: {p} parameters, {nodes} nodes, Adam");

    let mk = || {
        let mut cfg = TrainConfig::nmt_like(model, nodes, 7);
        cfg.epochs = 10.0; // 10 × 30 = 300 steps
        cfg.steps_per_epoch = 30;
        cfg.optim = OptimKind::Adam;
        cfg.lr = LrSchedule::constant(1e-3);
        cfg.eval_every_epochs = 1.0;
        cfg
    };

    let mut rows = Vec::new();
    for (name, algo) in [("SGP-Adam", "sgp"), ("AR-Adam", "ar-sgd")] {
        println!("\n=== {name}: {} steps ===", mk().total_iters());
        let mut trainer =
            TrainerBuilder::new(&rt).config(mk()).algorithm(algo).build()?;
        let r = trainer.run()?;
        r.write_csv(&results_dir())?;
        println!("epoch   val-NLL   val-ppl   sim-time");
        for e in &r.evals {
            println!(
                "{:>5.1}   {:>7.4}   {:>7.2}   {:>7.1}s",
                e.epoch,
                e.val_loss,
                e.val_loss.exp(),
                e.sim_time_s
            );
        }
        rows.push((name, r));
    }

    println!("\n=== summary (300 steps, 8 nodes, 10 GbE sim) ===");
    println!("method      train-loss   val-NLL   val-ppl   sim-time    wall");
    for (name, r) in &rows {
        println!(
            "{:<10}  {:>10.4}   {:>7.4}   {:>7.2}   {:>7.1}s   {:>5.1}s",
            name,
            r.final_train_loss(),
            r.final_val_loss,
            r.final_val_loss.exp(),
            r.sim_total_s,
            r.wall_s
        );
    }
    let (sgp, ar) = (&rows[0].1, &rows[1].1);
    println!(
        "\nSGP speedup over AllReduce (simulated): {:.2}×; NLL gap: {:+.4}",
        ar.sim_total_s / sgp.sim_total_s,
        sgp.final_val_loss - ar.final_val_loss
    );
    Ok(())
}

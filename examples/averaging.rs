//! Decentralized averaging study (Appendix A of the paper): PushSum over
//! the directed exponential graph reaches the exact average in log2(n)
//! iterations, beating complete-graph cycling and randomized peer
//! selection — shown two ways:
//!
//!  1. spectrally, via λ₂ of the mixing-matrix products (pure Rust), and
//!  2. numerically, by running the gossip rounds through the MXU-tiled
//!     Pallas `gossip_dense` artifact on the PJRT runtime.
//!
//!     make artifacts && cargo run --release --example averaging

use anyhow::Result;

use sgp::algorithms::{AlgoParams, DistributedAlgorithm, RoundCtx, Sgp};
use sgp::metrics::print_table;
use sgp::net::LinkModel;
use sgp::optim::OptimKind;
use sgp::rng::Pcg;
use sgp::runtime::Runtime;
use sgp::topology::{spectral, Schedule, TopologyKind};

fn main() -> Result<()> {
    let n = 32;

    // --- 1. spectral view ------------------------------------------------
    let mut rows = Vec::new();
    for (name, kind) in [
        ("exp-graph cycling", TopologyKind::OnePeerExp),
        ("complete-graph cycling", TopologyKind::CompleteCycling),
        ("random exp peer", TopologyKind::RandomExp),
        ("random any peer", TopologyKind::RandomAny),
    ] {
        let mut cells = vec![name.to_string()];
        for window in [1usize, 3, 5, 10] {
            let v = spectral::expected_lambda2(
                &Schedule::with_seed(kind, n, 1),
                window,
                10,
            );
            cells.push(format!("{v:.3}"));
        }
        rows.push(cells);
    }
    print_table(
        "λ₂ of k-step mixing products (n=32; 0 = exact consensus)",
        &["scheme", "k=1", "k=3", "k=5", "k=10"],
        &rows,
    );

    // --- 2. numerical view through the Pallas artifact --------------------
    let rt = Runtime::open_default()?;
    let meta = rt.manifest.artifact("gossip_dense_n32")?;
    let d = meta.d.unwrap_or(1024);
    let mut rng = Pcg::new(9);
    let x0: Vec<f32> = rng.gaussian_vec(n * d);

    let mut rows = Vec::new();
    for (name, kind) in [
        ("exp-graph cycling", TopologyKind::OnePeerExp),
        ("complete-graph cycling", TopologyKind::CompleteCycling),
    ] {
        let sched = Schedule::new(kind, n);
        let mut x = x0.clone();
        let mut w = vec![1.0f32; n];
        let target: Vec<f64> = (0..d)
            .map(|j| (0..n).map(|i| x[i * d + j] as f64).sum::<f64>() / n as f64)
            .collect();
        let mut cells = vec![name.to_string()];
        for k in 0..8u64 {
            let p = sched.mixing_matrix(k);
            let pf: Vec<f32> =
                (0..n * n).map(|i| p.at(i / n, i % n) as f32).collect();
            let (xn, wn, z) = rt.gossip_dense(n, &pf, &x, &w)?;
            x = xn;
            w = wn;
            if k % 2 == 1 {
                let err: f64 = (0..n)
                    .map(|i| {
                        (0..d)
                            .map(|j| {
                                let e = z[i * d + j] as f64 - target[j];
                                e * e
                            })
                            .sum::<f64>()
                            .sqrt()
                    })
                    .sum::<f64>()
                    / n as f64;
                cells.push(format!("{err:.2e}"));
            }
        }
        rows.push(cells);
    }
    print_table(
        "mean ‖zᵢ − ȳ‖ after k PushSum rounds via the Pallas dense-gossip HLO",
        &["scheme", "k=2", "k=4", "k=6", "k=8"],
        &rows,
    );

    // --- 3. the strategy trait (sanity: matches the artifact path) --------
    // Drive pure averaging through the `DistributedAlgorithm` API the
    // trainer uses: perturb the nodes apart with one fake gradient, then
    // let SGP's communicate() rounds pull them back into consensus.
    let params = AlgoParams::new(n, vec![0.0f32; d], OptimKind::Sgd);
    let mut alg = Sgp::with_topology(TopologyKind::OnePeerExp, &params);
    for i in 0..n {
        let g: Vec<f32> = x0[i * d..(i + 1) * d].iter().map(|v| -v).collect();
        alg.apply_step(i, &g, 1.0); // x_i ← x0 slice (SGD, lr=1)
    }
    let link = LinkModel::ethernet_10g();
    let comp = vec![0.1f64; n];
    for k in 0..5 {
        let ctx = RoundCtx::new(k, &comp, 4 * d, &link);
        alg.communicate(&ctx);
    }
    let (mean_dist, _, _) = alg.consensus_stats();
    println!(
        "\nDistributedAlgorithm trait after 5 exp-graph rounds: mean ‖zᵢ−x̄‖ = {mean_dist:.2e}"
    );
    Ok(())
}

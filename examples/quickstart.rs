//! Quickstart: train a small classifier with Stochastic Gradient Push on a
//! simulated 4-node cluster, all from the public API.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! What happens:
//!  * the PJRT runtime loads the AOT-compiled `train_mlp_small` HLO
//!    (JAX/Pallas-built, Python not involved at runtime),
//!  * four logical nodes run Alg. 1: local Nesterov step at the de-biased
//!    parameters, then one PushSum gossip exchange over the time-varying
//!    directed exponential graph,
//!  * the simulated 10 GbE cluster attaches wall-clock to every iteration.

use anyhow::Result;

use sgp::config::TrainConfig;
use sgp::coordinator::TrainerBuilder;
use sgp::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    let nodes = 4;

    let mut cfg = TrainConfig::imagenet_like("mlp_small", nodes, 42);
    cfg.epochs = 10.0;
    cfg.steps_per_epoch = 16;
    cfg.eval_every_epochs = 2.0;

    // Strategies are picked by registry name; swap "sgp" for any of
    // `sgp::algorithms::names()` (ar-sgd, dpsgd, adpsgd, dasgd, …).
    let mut trainer = TrainerBuilder::new(&rt).config(cfg).algorithm("sgp").build()?;
    let result = trainer.run()?;

    println!("\nepoch   train-loss   val-acc   consensus-dist   sim-time");
    for e in &result.evals {
        println!(
            "{:>5.1}   {:>10.4}   {:>6.1}%   {:>13.3e}   {:>7.1}s",
            e.epoch,
            result
                .iters
                .iter()
                .rev()
                .find(|r| r.iter <= e.iter)
                .map(|r| r.train_loss)
                .unwrap_or(f64::NAN),
            100.0 * e.val_metric,
            e.consensus_mean,
            e.sim_time_s,
        );
    }
    println!(
        "\nfinal: val acc {:.1}%  (simulated {:.0}s on 10 GbE, wall {:.1}s)",
        100.0 * result.final_val_metric,
        result.sim_total_s,
        result.wall_s
    );
    Ok(())
}

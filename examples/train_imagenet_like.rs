//! The ImageNet-protocol workload (Sec. 6.1 scaled down): sweep the
//! algorithm grid {AR-SGD, D-PSGD, SGP, 1-OSGP} on a 16-node simulated
//! 10 GbE cluster with the Goyal LR schedule, and print the Table-1-style
//! comparison plus the fixed-runtime-budget view of Table 5.
//!
//!     make artifacts && cargo run --release --example train_imagenet_like

use anyhow::Result;

use sgp::config::TrainConfig;
use sgp::coordinator::TrainerBuilder;
use sgp::experiments::results_dir;
use sgp::metrics::{hours, print_table};
use sgp::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    let nodes = 16;
    let epochs = 30.0;

    let mk = || {
        let mut cfg = TrainConfig::imagenet_like("mlp_small", nodes, 3);
        cfg.epochs = epochs;
        // Compress the Goyal schedule into the shorter run.
        cfg.lr.milestones = vec![epochs / 3.0, 2.0 * epochs / 3.0, 8.0 * epochs / 9.0];
        cfg.eval_every_epochs = epochs / 6.0;
        cfg
    };

    // The algorithm grid is a list of registry names — adding a method to
    // this sweep is one string (see `sgp::algorithms::REGISTRY`).
    let grid = vec![
        ("AR-SGD", "ar-sgd"),
        ("D-PSGD", "dpsgd"),
        ("SGP", "sgp"),
        ("1-OSGP", "osgp"),
    ];

    let mut rows = Vec::new();
    for (name, algo) in grid {
        eprintln!("[{name}] {} iters × {nodes} nodes", mk().total_iters());
        let r = TrainerBuilder::new(&rt)
            .config(mk())
            .algorithm(algo)
            .tau(1)
            .build()?
            .run()?;
        r.write_csv(&results_dir())?;
        rows.push(vec![
            name.to_string(),
            format!("{:.4}", r.final_train_loss()),
            format!("{:.1}%", 100.0 * r.final_val_metric),
            hours(r.sim_total_s),
            format!("{:.3}s", r.avg_iter_time()),
            format!("{:.1}s", r.wall_s),
        ]);
    }
    print_table(
        &format!("ImageNet-protocol analogue — {nodes} nodes, 10 GbE, {epochs} epochs"),
        &["method", "train loss", "val acc", "sim time", "s/iter", "wall"],
        &rows,
    );
    println!("\nloss/consensus curves written under results/");
    Ok(())
}

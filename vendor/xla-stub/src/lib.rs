//! Offline stub of the `xla` PJRT bindings (xla-rs API shape).
//!
//! The build container has no XLA toolchain, so this crate exposes the
//! exact types/methods `sgp::runtime` calls and fails at *runtime* with a
//! clear message instead of failing the *build*. Everything that needs it
//! (integration tests, benches, `repro train`) already skips gracefully
//! when the HLO artifacts are absent; `Runtime::new` surfaces this error
//! only when a manifest exists but no real backend is linked.
//!
//! To execute artifacts for real, point the workspace `xla` path
//! dependency at a full binding crate with this same surface.

use std::fmt;

/// Error type surfaced by every stubbed entry point.
pub struct XlaError(pub String);

const UNAVAILABLE: &str =
    "XLA/PJRT backend unavailable: built with vendor/xla-stub (swap the `xla` \
     path dependency for a real binding crate to execute HLO artifacts)";

fn unavailable<T>() -> Result<T> {
    Err(XlaError(UNAVAILABLE.to_string()))
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

/// PJRT client handle (stub: construction fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (stub: parsing fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle (stub: unreachable — compile always errors).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host literal (stub holds no data; ops on it error).
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_xs: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_not_silently() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        assert!(format!("{err}").contains("unavailable"));
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
    }
}

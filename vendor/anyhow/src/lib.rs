//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored crate
//! provides exactly the surface the workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`. Semantics match upstream for
//! that surface (context chains print as `caused by: ...` lines from
//! Debug). Swap the path dependency for the real crate when a registry is
//! available — no call site changes.

use std::error::Error as StdError;
use std::fmt;

/// Error type: a message plus an optional boxed source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + 'static>>,
}

/// `anyhow::Result<T>` — alias used across the workspace.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap an existing error with a higher-level context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: c.to_string(), source: Some(Box::new(Chained(self))) }
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> Vec<String> {
        let mut out = vec![self.msg.clone()];
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            out.push(e.to_string());
            cur = e.source();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, "\n\ncaused by: {e}")?;
            cur = e.source();
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as upstream).
// The wrapped error is flattened to its Display text (plus its rendered
// source, if any) so context chains never print a cause twice.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let msg = e.to_string();
        let source = e
            .source()
            .map(|s| Box::new(Flat(s.to_string())) as Box<dyn StdError + 'static>);
        Error { msg, source }
    }
}

/// Leaf node carrying a pre-rendered source message.
#[derive(Debug)]
struct Flat(String);

impl fmt::Display for Flat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl StdError for Flat {}

/// Internal adapter so an `Error` can sit inside a `dyn StdError` chain.
struct Chained(Error);

impl fmt::Display for Chained {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)
    }
}

impl fmt::Debug for Chained {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl StdError for Chained {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.0.source.as_deref()
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C>(self, c: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C>(self, c: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, c: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Assert a condition, early-returning an [`Error`] when it fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_chains_render_in_debug() {
        let e: Error = Err::<(), _>(io_err()).context("opening config").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("opening config"));
        assert!(dbg.contains("missing thing"));
        assert_eq!(e.chain(), vec!["opening config", "missing thing"]);
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
        let n = 3;
        let e = anyhow!("bad value {n}");
        assert_eq!(format!("{e}"), "bad value 3");
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert!(f(3).is_ok());
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(g().is_err());
    }
}
